//! Execution plans — the optimizer's output consumed by the simulator and
//! the runtime engine.
//!
//! A plan records, per node, how the horizontal optimization mapped it onto
//! the device: how many DSP units it runs on, along which dimensions the
//! feature map was partitioned (paper §4.2.1), how the parameters were split
//! to fit private L2 (§4.2.2), and whether the vertical optimization linked
//! its output layout to the consumer's read order (§4.1).

use crate::graph::NodeId;

/// Optimization level of a deployment — the paper's Fig. 7 ablation arms.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OptLevel {
    /// No HO, no VO (fixed hardware-oblivious partition).
    Vanilla,
    /// Horizontal optimization only (DSP-aware operator split).
    HoOnly,
    /// Full Xenos: HO + VO (operator linking).
    Full,
}

impl OptLevel {
    /// Display name matching the paper's figure legends.
    pub fn label(self) -> &'static str {
        match self {
            OptLevel::Vanilla => "Vanilla",
            OptLevel::HoOnly => "HO",
            OptLevel::Full => "Xenos(HO+VO)",
        }
    }
}

/// Feature-map partition dimension (paper §4.2.1; `inC` is deliberately
/// excluded — it would add cross-unit reductions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionDim {
    /// Output-channel partition (preferred: kernels distribute, no halo).
    OutC,
    /// Input-height partition (needs boundary halo rows).
    InH,
    /// Input-width partition (needs boundary halo columns).
    InW,
}

/// Parameter split dimension (paper §4.2.2 priority K → C → R → S).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitDim {
    /// Output channels — no extra computation.
    K,
    /// Input channels — adds a reduction.
    C,
    /// Kernel height — adds a reduction.
    R,
    /// Kernel width — adds a reduction.
    S,
}

/// Contiguous near-even share `idx` of `0..total` split `ways` ways: the
/// first `total % ways` shares get one extra element. Shares beyond `total`
/// come back empty. This is the single chunking rule every shard consumer
/// (the d-Xenos cluster runtime, shard-weight extraction, halo bookkeeping)
/// uses, so producers and consumers always agree on slice boundaries.
pub fn even_share(total: usize, ways: usize, idx: usize) -> (usize, usize) {
    let ways = ways.max(1);
    if idx >= ways {
        return (total, total);
    }
    let base = total / ways;
    let rem = total % ways;
    let start = idx * base + idx.min(rem);
    let end = start + base + usize::from(idx < rem);
    (start, end)
}

/// One rank's slice of a partitioned dimension. The d-Xenos shard-weight
/// extraction (`dist::exec::shard`) materializes these to cut parameter
/// tensors; workers re-derive the same boundaries from [`even_share`], so
/// the slice itself never needs to travel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSlice {
    /// Shard rank the slice belongs to.
    pub rank: usize,
    /// Partitioned dimension.
    pub dim: PartitionDim,
    /// Slice start (inclusive).
    pub start: usize,
    /// Slice end (exclusive).
    pub end: usize,
}

impl ShardSlice {
    /// True when the slice carries no work (more ranks than elements).
    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }
}

/// All `ways` slices of `0..total` along `dim`, in rank order — what a
/// `p`-way distributed partition of one node serializes to.
pub fn shard_slices(dim: PartitionDim, total: usize, ways: usize) -> Vec<ShardSlice> {
    (0..ways.max(1))
        .map(|rank| {
            let (start, end) = even_share(total, ways, rank);
            ShardSlice { rank, dim, start, end }
        })
        .collect()
}

/// How a node's parameters are split into L2-resident chunks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParamSplit {
    /// Split dimension.
    pub dim: SplitDim,
    /// Number of chunks per DSP unit.
    pub chunks: usize,
    /// Bytes of one chunk.
    pub chunk_bytes: u64,
    /// True if the split dimension requires a partial-sum reduction.
    pub needs_reduction: bool,
}

/// Per-node mapping decisions.
#[derive(Debug, Clone, PartialEq)]
pub struct NodePlan {
    /// The node this plan is for.
    pub node: NodeId,
    /// DSP units assigned.
    pub units: usize,
    /// Partition dimensions applied, outermost first, with their way counts.
    pub partition: Vec<(PartitionDim, usize)>,
    /// Load-balance efficiency in (0, 1]: 1.0 = perfectly even shares.
    pub balance: f64,
    /// Parameter split (None when parameters already fit or none exist).
    pub param_split: Option<ParamSplit>,
    /// Whether the per-unit parameter working set fits private L2.
    pub params_fit_l2: bool,
    /// Whether the runtime double-buffers DMA so memory traffic overlaps
    /// compute (§4.2.2). The hardware-oblivious Vanilla deployment lacks
    /// this discipline and serializes the two.
    pub dma_overlap: bool,
    /// Whether VO linked this node's output layout to its consumer.
    pub linked: bool,
    /// Extra bytes written due to halo replication introduced by linking a
    /// k>1 conv or by inH/inW partitioning (the paper's "data redundancy").
    pub halo_bytes: u64,
}

impl NodePlan {
    /// A serial, unoptimized plan for a node (single unit, no split).
    pub fn serial(node: NodeId) -> NodePlan {
        NodePlan {
            node,
            units: 1,
            partition: Vec::new(),
            balance: 1.0,
            param_split: None,
            params_fit_l2: true,
            dma_overlap: true,
            linked: false,
            halo_bytes: 0,
        }
    }

    /// Total partition ways (product over dimensions).
    pub fn ways(&self) -> usize {
        self.partition.iter().map(|(_, w)| *w).product::<usize>().max(1)
    }
}

/// A full deployment plan for a graph on a device.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// Ablation arm this plan implements.
    pub level: OptLevel,
    /// Device preset name.
    pub device: String,
    /// Per-node plans, indexed by `NodeId`.
    pub nodes: Vec<NodePlan>,
}

impl ExecutionPlan {
    /// Plan lookup by node.
    pub fn node(&self, id: NodeId) -> &NodePlan {
        &self.nodes[id]
    }

    /// Peak DSP units used by any single node.
    pub fn peak_units(&self) -> usize {
        self.nodes.iter().map(|n| n.units).max().unwrap_or(0)
    }

    /// Number of linked (VO-optimized) edges.
    pub fn linked_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.linked).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ways_multiplies_partitions() {
        let mut p = NodePlan::serial(0);
        assert_eq!(p.ways(), 1);
        p.partition = vec![(PartitionDim::OutC, 8), (PartitionDim::InH, 2)];
        assert_eq!(p.ways(), 16);
    }

    #[test]
    fn labels_match_paper() {
        assert_eq!(OptLevel::Vanilla.label(), "Vanilla");
        assert_eq!(OptLevel::Full.label(), "Xenos(HO+VO)");
    }

    #[test]
    fn even_share_partitions_exactly() {
        for (total, ways) in [(10, 3), (4, 8), (0, 4), (16, 4), (7, 7)] {
            let mut covered = 0;
            for idx in 0..ways {
                let (s, e) = even_share(total, ways, idx);
                assert_eq!(s, covered, "total={total} ways={ways} idx={idx}");
                assert!(e >= s && e <= total);
                covered = e;
            }
            assert_eq!(covered, total);
        }
        // Shares differ by at most one element.
        let sizes: Vec<usize> =
            (0..3).map(|i| { let (s, e) = even_share(10, 3, i); e - s }).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
    }

    #[test]
    fn shard_slices_round_trip() {
        let slices = shard_slices(PartitionDim::OutC, 10, 4);
        assert_eq!(slices.len(), 4);
        assert_eq!(slices[0].start, 0);
        assert_eq!(slices[3].end, 10);
        assert!(slices.iter().all(|s| !s.is_empty()));
        let empty = shard_slices(PartitionDim::InH, 2, 4);
        assert!(empty[3].is_empty());
    }

    #[test]
    fn plan_aggregates() {
        let mut a = NodePlan::serial(0);
        a.units = 4;
        let mut b = NodePlan::serial(1);
        b.units = 16;
        b.linked = true;
        let plan =
            ExecutionPlan { level: OptLevel::Full, device: "d".into(), nodes: vec![a, b] };
        assert_eq!(plan.peak_units(), 16);
        assert_eq!(plan.linked_count(), 1);
    }
}
