//! Precision planning — the graph rewrite behind INT8 execution.
//!
//! Like operator linking (§4.1), quantization is expressed as **edge
//! metadata, not new operator kinds**: the pass assigns every node a
//! [`QuantKind`] and the engines realize the implied quantize/dequantize
//! boundaries (the annotated graph records them as [`DType::I8`] edges,
//! which the simulator and the wire protocol already price at one byte
//! per element).
//!
//! The folding rule mirrors classic q/dq elimination: a *pass-through*
//! operator (pure selection/copy — ReLU, max-pool, slice, shuffle,
//! upsample, transpose, concat-of-like-scales is deliberately excluded)
//! maps i8-grid values to i8-grid values on the **same** grid, so the
//! dequantize→(op)→quantize pair around it cancels exactly and the
//! operator runs inside the quantized region with zero extra error.
//! Everything else either runs on the integer kernels ([`QuantKind::
//! IntDot`]) or computes in f32 and *re-quantizes* its output onto its
//! own calibrated grid ([`QuantKind::Requant`]).
//!
//! **Grid-snapping semantics** (the invariant the engines rely on): a
//! value "on a grid" means every element is exactly `k·scale` for an
//! `i8` code `k ∈ [-127, 127]`, with the scale resolved per channel
//! through `grid_of`. Snapped values survive quantize→dequantize
//! round-trips losslessly, which is what makes i8 wire payloads and
//! shard-local requantization exact; the rounding mode that defines `k`
//! is pinned crate-wide in [`crate::quant::quant1`] (ties away from
//! zero) and reproduced by the fixed-point kernel epilogue
//! ([`crate::quant::fix_requant1`]).
//!
//! The plan additionally marks **dequantize boundaries**
//! ([`QuantPlan::needs_f32`]): activations are i8-resident everywhere
//! (codes + grid travel between operators as
//! [`QTensor`](crate::quant::QTensor)s), and f32 is materialized only on
//! edges into f32-computed consumers and at graph outputs. An edge
//! between two adjacent `IntDot` nodes is consumed as raw codes — the
//! i8→f32→i8 snap round-trip the engines used to pay per edge is gone
//! (the tentpole of the end-to-end integer dataflow work; the engines'
//! `snap_roundtrips` counter pins it at zero).

use crate::graph::{DType, Graph, NodeId, OpKind, PoolKind};

/// How one node executes under INT8 precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantKind {
    /// Integer kernel (i8 × i8 → i32): conv family and matmul. Output is
    /// requantized onto the node's calibrated grid.
    IntDot,
    /// Pure selection/copy that preserves the input's i8 grid — the
    /// folded q/dq case; no requantization, no extra error.
    Passthrough,
    /// f32 arithmetic, output snapped onto the node's calibrated grid (a
    /// quantize boundary). Graph inputs are Requant: that is the inserted
    /// quantize node at the graph's edge.
    Requant,
}

/// A whole-graph precision assignment.
#[derive(Debug, Clone)]
pub struct QuantPlan {
    /// Per-node execution kind, indexed by `NodeId`.
    pub kinds: Vec<QuantKind>,
    /// For every node, the node whose activation grid its output lives
    /// on: itself for `IntDot`/`Requant`, the transitive producer for
    /// `Passthrough` chains. Engines read activation scales through this
    /// indirection so folded operators stay exactly on their producer's
    /// grid.
    pub grid_of: Vec<NodeId>,
    /// Per-node **dequantize-boundary** annotation: `true` when the
    /// node's output is additionally materialized as f32 at runtime —
    /// because it is a graph output, or because some consumer computes in
    /// f32 (`Requant`/`Passthrough` kinds). This is planning metadata
    /// (reporting via [`QuantPlan::dequant_boundaries`], `xenos
    /// quantize`); the engines realize the same boundaries by consumer
    /// kind. Every activation is i8-resident (a
    /// [`crate::quant::QTensor`] of codes); edges between adjacent
    /// `IntDot` nodes have `needs_f32 = false` on the producer and are
    /// consumed as raw codes with **no** i8→f32→i8 round-trip.
    pub needs_f32: Vec<bool>,
}

impl QuantPlan {
    /// Number of integer-kernel nodes.
    pub fn int_nodes(&self) -> usize {
        self.kinds.iter().filter(|k| **k == QuantKind::IntDot).count()
    }

    /// Number of folded quantize/dequantize pairs (pass-through nodes).
    pub fn folded(&self) -> usize {
        self.kinds.iter().filter(|k| **k == QuantKind::Passthrough).count()
    }

    /// Number of requantization boundaries.
    pub fn boundaries(&self) -> usize {
        self.kinds.iter().filter(|k| **k == QuantKind::Requant).count()
    }

    /// Number of graph edges consumed directly as i8 codes (edges into
    /// `IntDot` consumers) — the integer-resident dataflow the engines
    /// execute with zero f32 materialization.
    pub fn resident_edges(&self, g: &Graph) -> usize {
        g.nodes
            .iter()
            .filter(|n| self.kinds[n.id] == QuantKind::IntDot)
            .map(|n| n.inputs.len())
            .sum()
    }

    /// Number of dequantize boundaries the engines realize: edges into
    /// f32-computed consumers plus graph outputs.
    pub fn dequant_boundaries(&self, g: &Graph) -> usize {
        let edges: usize = g
            .nodes
            .iter()
            .filter(|n| self.kinds[n.id] != QuantKind::IntDot)
            .map(|n| n.inputs.len())
            .sum();
        edges + g.outputs.len()
    }
}

/// True for operators that map i8-grid values to the same i8 grid:
/// selections and copies with a single data input. Average pooling and
/// all arithmetic are excluded (their outputs leave the grid), as is
/// concat (its inputs generally live on different grids).
fn passthrough(op: &OpKind) -> bool {
    matches!(
        op,
        OpKind::Relu
            | OpKind::Slice { .. }
            | OpKind::ChannelShuffle { .. }
            | OpKind::Upsample { .. }
            | OpKind::Transpose
    ) || matches!(op, OpKind::Pool(p) if p.kind == PoolKind::Max)
}

/// Assign a precision kind to every node of `g` and fold pass-through
/// chains onto their producers' grids.
pub fn plan_quant(g: &Graph) -> QuantPlan {
    let mut kinds = Vec::with_capacity(g.len());
    let mut grid_of: Vec<NodeId> = Vec::with_capacity(g.len());
    for n in &g.nodes {
        let kind = match &n.op {
            OpKind::Conv(_) | OpKind::Cbr(_) | OpKind::Cbra(..) | OpKind::Cbrm(..) => {
                QuantKind::IntDot
            }
            OpKind::MatMul(_) => QuantKind::IntDot,
            op if passthrough(op) => QuantKind::Passthrough,
            _ => QuantKind::Requant,
        };
        // Topological order: producers are already resolved.
        let grid = if kind == QuantKind::Passthrough {
            grid_of[n.inputs[0]]
        } else {
            n.id
        };
        kinds.push(kind);
        grid_of.push(grid);
    }
    // Dequantize boundaries: a node's codes must additionally decode to
    // f32 when an f32-computed consumer (anything but IntDot) reads them
    // or when the node is a graph output. IntDot consumers read raw codes.
    let mut needs_f32 = vec![false; g.len()];
    for n in &g.nodes {
        if kinds[n.id] != QuantKind::IntDot {
            for &i in &n.inputs {
                needs_f32[i] = true;
            }
        }
    }
    for &o in &g.outputs {
        needs_f32[o] = true;
    }
    QuantPlan { kinds, grid_of, needs_f32 }
}

/// The annotated-graph rewrite: a copy of `g` whose activation edges
/// carry [`DType::I8`]. Every [`QuantKind`] keeps its output on an i8
/// grid (IntDot/Requant snap, Passthrough inherits), so every edge is
/// annotated. This is what `xenos quantize` reports and what byte-level
/// accounting (simulator, halo/all-gather traffic) prices — the numeric
/// engines consult the [`QuantPlan`] directly.
pub fn annotate_quant(g: &Graph) -> Graph {
    let mut out = g.clone();
    for n in out.nodes.iter_mut() {
        n.out.dtype = DType::I8;
    }
    out
}

/// Activation bytes of a graph (sum over non-input edges) — used to
/// report the f32 → i8 traffic cut.
pub fn activation_bytes(g: &Graph) -> u64 {
    g.nodes
        .iter()
        .filter(|n| !matches!(n.op, OpKind::Input))
        .map(|n| n.out.bytes())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Shape};

    fn mixed_graph() -> Graph {
        let mut b = GraphBuilder::new("qplan_t");
        let x = b.input("x", Shape::nchw(1, 4, 8, 8));
        let c = b.conv("c", x, 8, 3, 1, 1);
        let bn = b.bn("bn", c);
        let r = b.relu("r", bn);
        let mp = b.maxpool("mp", r, 2, 2);
        let ap = b.avgpool("ap", mp, 2, 2);
        let f = b.fc("fc", ap, 5);
        let sm = b.softmax("sm", f);
        b.output(sm);
        b.finish()
    }

    #[test]
    fn kinds_follow_operator_classes() {
        let g = mixed_graph();
        let p = plan_quant(&g);
        let kind_of = |name: &str| {
            let n = g.nodes.iter().find(|n| n.name == name).unwrap();
            p.kinds[n.id]
        };
        assert_eq!(kind_of("x"), QuantKind::Requant); // inserted input quantize
        assert_eq!(kind_of("c"), QuantKind::IntDot);
        assert_eq!(kind_of("bn"), QuantKind::Requant);
        assert_eq!(kind_of("r"), QuantKind::Passthrough);
        assert_eq!(kind_of("mp"), QuantKind::Passthrough);
        assert_eq!(kind_of("ap"), QuantKind::Requant);
        assert_eq!(kind_of("fc"), QuantKind::IntDot);
        assert_eq!(kind_of("sm"), QuantKind::Requant);
        assert_eq!(p.int_nodes(), 2);
        assert_eq!(p.folded(), 2);
    }

    #[test]
    fn passthrough_chains_fold_to_the_producer_grid() {
        let g = mixed_graph();
        let p = plan_quant(&g);
        let id_of = |name: &str| g.nodes.iter().find(|n| n.name == name).unwrap().id;
        // relu and maxpool both live on bn's grid (the q/dq pairs folded).
        assert_eq!(p.grid_of[id_of("r")], id_of("bn"));
        assert_eq!(p.grid_of[id_of("mp")], id_of("bn"));
        // Boundary nodes own their grid.
        assert_eq!(p.grid_of[id_of("ap")], id_of("ap"));
        assert_eq!(p.grid_of[id_of("c")], id_of("c"));
    }

    #[test]
    fn intdot_chains_are_i8_resident_and_boundaries_are_marked() {
        // conv -> conv adjacency (the MobileNet-style hot path): the
        // producer edge is i8-resident — no f32 materialization.
        let mut b = GraphBuilder::new("qplan_chain");
        let x = b.input("x", Shape::nchw(1, 4, 8, 8));
        let c1 = b.conv("c1", x, 8, 3, 1, 1);
        let c2 = b.conv("c2", c1, 8, 1, 1, 0);
        let sm = b.softmax("sm", c2);
        b.output(sm);
        let g = b.finish();
        let p = plan_quant(&g);
        let id_of = |name: &str| g.nodes.iter().find(|n| n.name == name).unwrap().id;
        // c1 feeds only the IntDot c2: codes-only edge.
        assert!(!p.needs_f32[id_of("c1")], "IntDot->IntDot edge must stay i8");
        // x feeds IntDot c1: also codes-only.
        assert!(!p.needs_f32[id_of("x")]);
        // c2 feeds the f32-computed softmax: a dequantize boundary.
        assert!(p.needs_f32[id_of("c2")]);
        // The graph output is always a boundary.
        assert!(p.needs_f32[id_of("sm")]);
        // Edge accounting: x->c1, c1->c2 resident; c2->sm + output = 2
        // boundaries.
        assert_eq!(p.resident_edges(&g), 2);
        assert_eq!(p.dequant_boundaries(&g), 2);
    }

    #[test]
    fn annotate_marks_edges_i8_and_quarters_traffic() {
        let g = mixed_graph();
        let q = annotate_quant(&g);
        assert!(q.nodes.iter().all(|n| n.out.dtype == DType::I8));
        let f32_bytes = activation_bytes(&g);
        let i8_bytes = activation_bytes(&q);
        assert_eq!(f32_bytes, 4 * i8_bytes);
    }
}
