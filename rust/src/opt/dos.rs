//! Horizontal dataflow optimization — **DSP-aware operator split** (DOS,
//! paper §4.2).
//!
//! Two stages per operator, both driven by the device model rather than by
//! enumeration (the paper's argument against TASO/PET §8):
//!
//! * **Feature-map partition** (§4.2.1): priority `outC` → `inH` → `inW`;
//!   `inC` is never used (it would add cross-unit reductions). `outC` is
//!   preferred because kernels distribute to private L2 with no halo;
//!   `inH`/`inW` splits pay boundary replication.
//! * **Parameter split** (§4.2.2): priority `K` → `C`/`R`/`S`; chunks are
//!   sized to fit half the private L2 (double-buffered DMA), and non-K
//!   splits are marked as needing a partial-sum reduction.

use super::plan::{ExecutionPlan, NodePlan, OptLevel, ParamSplit, PartitionDim, SplitDim};
use crate::graph::{Graph, Node, OpKind};
use crate::hw::DeviceModel;
use crate::util::ceil_div;

/// Work elements along a dimension partitioned `ways` ways: the balance
/// efficiency (1.0 = perfectly even).
fn balance_of(dim: usize, ways: usize) -> f64 {
    if ways <= 1 || dim == 0 {
        return 1.0;
    }
    let share = ceil_div(dim, ways);
    dim as f64 / (ways * share) as f64
}

/// Below this output size an operator stays serial — fan-out/sync overhead
/// dwarfs the work (tuned against the op_overhead of the presets). The
/// parallel executor (`ops::par_exec`) gates on the same constant so the
/// planner and the runtime agree about which nodes parallelize.
pub const MIN_PARALLEL_ELEMS: usize = 4096;

/// Plan one node under DOS.
pub fn plan_node_dos(_g: &Graph, node: &Node, device: &DeviceModel, link_aware: bool) -> NodePlan {
    let mut plan = NodePlan::serial(node.id);
    let out = &node.out;
    let units_avail = device.dsp_units;

    match &node.op {
        OpKind::Conv(a) | OpKind::Cbr(a) | OpKind::Cbra(a, _) | OpKind::Cbrm(a, _) => {
            let (oc, oh) = (a.out_c, out.shape.h().max(1));
            // outC first: kernels distribute to L2, feature map stays shared.
            let ways_c = units_avail.min(oc).max(1);
            let mut partition = vec![(PartitionDim::OutC, ways_c)];
            let mut balance = balance_of(oc, ways_c);
            let mut halo = 0u64;
            // Only if kernels can't use every unit, split rows too (§4.2.1:
            // "Only if the kernels cannot be evenly distributed across DSP
            // units, DOS will seek further partition by inH/inW").
            let rem = units_avail / ways_c;
            if rem > 1 && oh > 1 {
                let ways_h = rem.min(oh);
                partition.push((PartitionDim::InH, ways_h));
                balance *= balance_of(oh, ways_h);
                // Boundary rows replicate (k-1) input rows per cut.
                if a.kh > 1 {
                    let in_row_bytes =
                        (out.shape.w() * a.stride * a.in_c * 4) as u64;
                    halo += (ways_h as u64 - 1) * (a.kh as u64 - 1) * in_row_bytes;
                }
            }
            plan.units = partition.iter().map(|(_, w)| *w).product();
            plan.partition = partition;
            plan.balance = balance;
            plan.halo_bytes = halo;

            // Parameter split to L2 (half capacity: double-buffered DMA).
            let budget = (device.l2.capacity / 2).max(1);
            let weight_bytes = node.op.param_count() * 4;
            let per_unit_oc = ceil_div(a.out_c, plan.ways_outc());
            let slice_bytes = ((a.in_c / a.groups) * a.kh * a.kw * 4) as u64;
            let per_unit_bytes = per_unit_oc as u64 * slice_bytes;
            if weight_bytes > 0 && per_unit_bytes > budget {
                if slice_bytes <= budget {
                    // K-split: chunks of whole output channels. Free.
                    let ch_per_chunk = (budget / slice_bytes).max(1) as usize;
                    let chunks = ceil_div(per_unit_oc, ch_per_chunk);
                    plan.param_split = Some(ParamSplit {
                        dim: SplitDim::K,
                        chunks,
                        chunk_bytes: ch_per_chunk as u64 * slice_bytes,
                        needs_reduction: false,
                    });
                } else {
                    // One kernel slice alone exceeds L2: split input channels.
                    let sub = ceil_div(slice_bytes as usize, budget as usize);
                    plan.param_split = Some(ParamSplit {
                        dim: SplitDim::C,
                        chunks: per_unit_oc * sub,
                        chunk_bytes: ceil_div(slice_bytes as usize, sub) as u64,
                        needs_reduction: true,
                    });
                }
            }
            plan.params_fit_l2 = plan
                .param_split
                .map(|s| s.chunk_bytes <= budget)
                .unwrap_or(per_unit_bytes <= budget);
        }
        OpKind::MatMul(m) => {
            let rows = out.shape.numel() / m.n;
            // Parallelize by arithmetic volume, not output size: an LSTM
            // gate is a [1,k]x[k,n] product — tiny output, real work.
            if node.macs() >= MIN_PARALLEL_ELEMS as u64 * 4 {
                // Split the n (K-like) dimension: weights distribute freely.
                let ways = units_avail.min(m.n).max(1);
                plan.units = ways;
                plan.partition = vec![(PartitionDim::OutC, ways)];
                plan.balance = balance_of(m.n, ways);
            }
            let budget = (device.l2.capacity / 2).max(1);
            let weight_bytes = node.op.param_count() * 4;
            if m.weighted && weight_bytes > 0 {
                let per_unit = ceil_div(weight_bytes as usize, plan.units.max(1)) as u64;
                if per_unit > budget {
                    let col_bytes = (m.k * 4) as u64; // one output column
                    if col_bytes <= budget {
                        let cols = (budget / col_bytes).max(1);
                        let per_unit_cols = ceil_div(m.n, plan.units.max(1));
                        plan.param_split = Some(ParamSplit {
                            dim: SplitDim::K,
                            chunks: ceil_div(per_unit_cols, cols as usize),
                            chunk_bytes: cols * col_bytes,
                            needs_reduction: false,
                        });
                    } else {
                        let sub = ceil_div(col_bytes as usize, budget as usize);
                        plan.param_split = Some(ParamSplit {
                            dim: SplitDim::C,
                            chunks: ceil_div(m.n, plan.units.max(1)) * sub,
                            chunk_bytes: ceil_div(col_bytes as usize, sub) as u64,
                            needs_reduction: true,
                        });
                    }
                }
                plan.params_fit_l2 = plan
                    .param_split
                    .map(|s| s.chunk_bytes <= budget)
                    .unwrap_or(ceil_div(weight_bytes as usize, plan.units.max(1)) as u64 <= budget);
            }
            let _ = rows;
        }
        // Pooling / element-wise / normalization: spatially parallel, no
        // parameters to split.
        OpKind::Pool(_)
        | OpKind::Relu
        | OpKind::Sigmoid
        | OpKind::Tanh
        | OpKind::Gelu
        | OpKind::Softmax
        | OpKind::LayerNorm
        | OpKind::Add
        | OpKind::Mul
        | OpKind::Mac
        | OpKind::BatchNorm
        | OpKind::Bias => {
            let elems = out.shape.numel();
            if elems >= MIN_PARALLEL_ELEMS {
                let rows = if out.shape.is_fm() {
                    out.shape.c() * out.shape.h()
                } else {
                    out.shape.dims[0]
                };
                let ways = units_avail.min(rows).max(1);
                plan.units = ways;
                plan.partition = vec![(PartitionDim::InH, ways)];
                plan.balance = balance_of(rows, ways);
            }
        }
        // Pure data movement & inputs stay serial (DMA-driven).
        OpKind::Input
        | OpKind::Concat
        | OpKind::Slice { .. }
        | OpKind::Transpose
        | OpKind::ChannelShuffle { .. }
        | OpKind::Upsample { .. } => {}
    }

    if link_aware {
        // The linking pass already rewrote layouts; mark restructured
        // producers and price standard-conv replication (paper §4.1: "the
        // operator linking technique can also incur data redundancy ...
        // of standard convolution").
        let natural = node.op.natural_write(out);
        if node.out.layout != natural {
            plan.linked = true;
            if let Some(a) = node.op.conv_attrs() {
                if !a.is_pointwise() && !a.is_depthwise() {
                    plan.halo_bytes += out.bytes() * 15 / 100;
                }
            }
        }
    }
    plan
}

/// Plan one node for the hardware-oblivious Vanilla baseline: a fixed
/// `vanilla_units`-way output-channel split, no L2 fitting, no linking.
pub fn plan_node_vanilla(node: &Node, device: &DeviceModel) -> NodePlan {
    let mut plan = NodePlan::serial(node.id);
    plan.dma_overlap = false; // no double-buffering discipline
    let out = &node.out;
    let units = device.vanilla_units.max(1);
    match &node.op {
        OpKind::Conv(a) | OpKind::Cbr(a) | OpKind::Cbra(a, _) | OpKind::Cbrm(a, _) => {
            plan.units = units;
            plan.partition = vec![(PartitionDim::OutC, units)];
            // Fixed split ignores the actual channel count: idle units and
            // ragged shares both waste capacity.
            plan.balance = if a.out_c >= units {
                balance_of(a.out_c, units)
            } else {
                a.out_c as f64 / units as f64
            };
            let budget = device.l2.capacity; // no double-buffer discipline
            let per_unit = ceil_div(node.op.param_count() as usize * 4, units) as u64;
            plan.params_fit_l2 = per_unit <= budget;
        }
        OpKind::MatMul(m) => {
            // The fixed scheme spreads FC columns over the units but never
            // checks residency.
            plan.units = units.min(m.n).max(1);
            plan.partition = vec![(PartitionDim::OutC, plan.units)];
            plan.balance = balance_of(m.n, plan.units);
            let per_unit = ceil_div(node.op.param_count() as usize * 4, plan.units) as u64;
            plan.params_fit_l2 = per_unit <= device.l2.capacity;
        }
        _ => {
            let elems = out.shape.numel();
            if elems >= MIN_PARALLEL_ELEMS && !matches!(node.op, OpKind::Input) {
                plan.units = units.min(elems / 64).max(1);
                plan.partition = vec![(PartitionDim::InH, plan.units)];
                plan.balance = 0.85; // fixed split, typically ragged
            }
        }
    }
    plan
}

impl NodePlan {
    /// Ways of the outC partition dimension (1 if absent).
    pub fn ways_outc(&self) -> usize {
        self.partition
            .iter()
            .find(|(d, _)| *d == PartitionDim::OutC)
            .map(|(_, w)| *w)
            .unwrap_or(1)
    }
}

/// Plan a whole graph at a given level. The graph must already be fused
/// (and, for `Full`, linked).
pub fn plan_graph(g: &Graph, device: &DeviceModel, level: OptLevel) -> ExecutionPlan {
    let nodes = g
        .nodes
        .iter()
        .map(|n| match level {
            OptLevel::Vanilla => plan_node_vanilla(n, device),
            OptLevel::HoOnly => plan_node_dos(g, n, device, false),
            OptLevel::Full => plan_node_dos(g, n, device, true),
        })
        .collect();
    ExecutionPlan { level, device: device.name.clone(), nodes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Shape};
    use crate::hw::presets;

    fn conv_graph(in_c: usize, out_c: usize, k: usize, hw: usize) -> Graph {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", Shape::nchw(1, in_c, hw, hw));
        let c = b.conv("c", x, out_c, k, 1, k / 2);
        b.output(c);
        b.finish()
    }

    #[test]
    fn outc_partition_uses_all_tms_units() {
        let g = conv_graph(32, 64, 3, 28);
        let d = presets::tms320c6678();
        let p = plan_node_dos(&g, g.node(1), &d, false);
        assert_eq!(p.units, 8);
        assert_eq!(p.partition[0], (PartitionDim::OutC, 8));
        assert!((p.balance - 1.0).abs() < 1e-9, "64/8 is even");
    }

    #[test]
    fn small_outc_spills_to_inh_partition() {
        // 4 output channels on 8 units: outC gives 4 ways, inH doubles it.
        let g = conv_graph(8, 4, 3, 32);
        let d = presets::tms320c6678();
        let p = plan_node_dos(&g, g.node(1), &d, false);
        assert_eq!(p.ways_outc(), 4);
        assert!(p.partition.iter().any(|(d, w)| *d == PartitionDim::InH && *w == 2));
        assert_eq!(p.units, 8);
        assert!(p.halo_bytes > 0, "inH split with k=3 pays halo");
    }

    #[test]
    fn param_split_fits_l2() {
        // 1024->1024 1x1 conv: 4 MB of weights, 128 per unit on 8 units ->
        // 512 KB per unit > 256 KB budget -> K-split into chunks.
        let g = conv_graph(1024, 1024, 1, 7);
        let d = presets::tms320c6678();
        let p = plan_node_dos(&g, g.node(1), &d, false);
        let s = p.param_split.expect("needs split");
        assert_eq!(s.dim, SplitDim::K);
        assert!(!s.needs_reduction);
        assert!(s.chunk_bytes <= d.l2.capacity / 2);
        assert!(p.params_fit_l2);
    }

    #[test]
    fn giant_kernel_slice_forces_c_split_with_reduction() {
        // in_c huge: one output-channel slice alone exceeds L2.
        let g = conv_graph(16384, 8, 3, 7);
        let d = presets::tms320c6678();
        let p = plan_node_dos(&g, g.node(1), &d, false);
        let s = p.param_split.expect("needs split");
        assert_eq!(s.dim, SplitDim::C);
        assert!(s.needs_reduction);
        assert!(p.params_fit_l2);
    }

    #[test]
    fn vanilla_never_splits_params() {
        let g = conv_graph(1024, 1024, 1, 7);
        let d = presets::tms320c6678();
        let p = plan_node_vanilla(g.node(1), &d);
        assert!(p.param_split.is_none());
        assert!(!p.params_fit_l2, "4MB/8 units does not fit 512KB L2");
    }

    #[test]
    fn vanilla_wastes_units_on_narrow_layers() {
        let g = conv_graph(8, 16, 3, 56);
        let d = presets::zcu102(); // vanilla_units = 96 > 16 channels
        let p = plan_node_vanilla(g.node(1), &d);
        assert!(p.balance < 0.2, "16 channels on 96 fixed ways: {}", p.balance);
    }

    #[test]
    fn zcu102_dos_uses_hundreds_of_units() {
        let g = conv_graph(64, 128, 3, 56);
        let d = presets::zcu102();
        let p = plan_node_dos(&g, g.node(1), &d, false);
        assert!(p.units >= 1024, "outC x inH should scale: {}", p.units);
    }

    #[test]
    fn tiny_ops_stay_serial() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", Shape::mat(1, 10));
        let s = b.softmax("s", x);
        b.output(s);
        let g = b.finish();
        let d = presets::tms320c6678();
        let p = plan_node_dos(&g, g.node(1), &d, false);
        assert_eq!(p.units, 1);
    }

    #[test]
    fn linked_std_conv_pays_halo() {
        let mut g = conv_graph(16, 32, 3, 28);
        // Simulate the linking pass: non-natural layout on the conv.
        g.node_mut(1).out.layout = crate::graph::DataLayout::Hwc;
        let d = presets::tms320c6678();
        let p = plan_node_dos(&g, g.node(1), &d, true);
        assert!(p.linked);
        assert!(p.halo_bytes >= g.node(1).out.bytes() * 15 / 100);
    }

    #[test]
    fn plan_graph_levels_differ() {
        let g = conv_graph(32, 64, 3, 56);
        let d = presets::zcu102();
        let v = plan_graph(&g, &d, OptLevel::Vanilla);
        let h = plan_graph(&g, &d, OptLevel::HoOnly);
        assert!(h.node(1).units > v.node(1).units);
    }
}
