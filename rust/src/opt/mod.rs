//! The Xenos optimizer — automatic dataflow-centric optimization (paper §4).
//!
//! Pipeline (all automatic, paper §4.4):
//!
//! 1. [`fusion::fuse_cbr`] — operator fusion preprocessing (§3).
//! 2. [`linking::link`] — vertical optimization: linked operators + layout
//!    metadata rewrite (§4.1). Applied only at [`OptLevel::Full`].
//! 3. [`dos`] — horizontal optimization: DSP-aware operator split producing
//!    the [`plan::ExecutionPlan`] (§4.2).
//! 4. [`quant`] — precision planning for INT8 execution: per-node
//!    quantize/dequantize boundaries with pass-through folding, expressed
//!    (like linking) as edge metadata rather than new operator kinds.
//!
//! The Fig. 7 ablation arms share the fused graph so the measured deltas
//! isolate HO and VO exactly as the paper's baselines do.

pub mod dos;
pub mod fusion;
pub mod linking;
pub mod plan;
pub mod quant;
pub mod rewrite;
pub mod search;

pub use linking::LinkRecord;
pub use plan::{
    even_share, shard_slices, ExecutionPlan, NodePlan, OptLevel, ParamSplit, PartitionDim,
    ShardSlice, SplitDim,
};

use std::time::{Duration, Instant};

use crate::graph::Graph;
use crate::hw::DeviceModel;

/// Options for [`optimize`].
#[derive(Debug, Clone, Copy)]
pub struct OptimizeOptions {
    /// Which ablation arm to produce.
    pub level: OptLevel,
    /// Run the cost-guided layout search (§8 extension) after the
    /// heuristic linking pass.
    pub search: bool,
}

impl Default for OptimizeOptions {
    fn default() -> Self {
        OptimizeOptions { level: OptLevel::Full, search: false }
    }
}

/// Result of the automatic optimization workflow.
#[derive(Debug)]
pub struct Optimized {
    /// The (possibly rewritten) graph to execute.
    pub graph: Graph,
    /// The per-node deployment plan.
    pub plan: ExecutionPlan,
    /// Applied vertical links (empty below `Full`).
    pub links: Vec<LinkRecord>,
    /// Number of CBR fusions performed.
    pub fused: usize,
    /// Wall-clock cost of the optimization itself (paper Table 2).
    pub elapsed: Duration,
}

/// Run the automatic optimization workflow on a model for a device.
pub fn optimize(g: &Graph, device: &DeviceModel, opts: OptimizeOptions) -> Optimized {
    optimize_src(g, device, opts, &crate::obs::profile::CostSource::Analytic)
}

/// [`optimize`] with an explicit cost source: with
/// `CostSource::Measured` the cost-guided layout search scores candidate
/// layouts against profiled op times (`xenos optimize --search
/// --measured-costs`) instead of the analytic model alone. Heuristic
/// passes (fusion, linking, DOS splits) are source-independent.
pub fn optimize_src(
    g: &Graph,
    device: &DeviceModel,
    opts: OptimizeOptions,
    source: &crate::obs::profile::CostSource,
) -> Optimized {
    let start = Instant::now();
    let (fused_graph, fused) = fusion::fuse_cbr(g);
    let (mut graph, mut links) = match opts.level {
        OptLevel::Full => {
            let linked = linking::link(&fused_graph);
            (linked.graph, linked.records)
        }
        _ => (fused_graph, Vec::new()),
    };
    if opts.search && opts.level == OptLevel::Full {
        let refined = search::refine_layouts_src(&mut graph, device, source);
        links.extend(search::as_link_records(&refined));
    }
    let plan = dos::plan_graph(&graph, device, opts.level);
    Optimized { graph, plan, links, fused, elapsed: start.elapsed() }
}

/// Convenience: fully optimize (the deployment default).
pub fn auto(g: &Graph, device: &DeviceModel) -> Optimized {
    optimize(g, device, OptimizeOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::hw::presets;
    use crate::ops::Interpreter;

    #[test]
    fn full_pipeline_on_mobilenet() {
        let g = models::mobilenet();
        let d = presets::tms320c6678();
        let o = auto(&g, &d);
        assert_eq!(o.fused, 27);
        assert!(!o.links.is_empty());
        assert_eq!(o.plan.nodes.len(), o.graph.len());
        assert!(o.plan.linked_count() > 10);
        o.graph.validate().unwrap();
    }

    #[test]
    fn levels_share_fused_structure() {
        let g = models::resnet18();
        let d = presets::zcu102();
        let v = optimize(&g, &d, OptimizeOptions { level: OptLevel::Vanilla, search: false });
        let h = optimize(&g, &d, OptimizeOptions { level: OptLevel::HoOnly, search: false });
        assert_eq!(v.graph.len(), h.graph.len(), "same fusion preprocessing");
        assert_eq!(v.links.len(), 0);
        assert_eq!(h.links.len(), 0);
    }

    #[test]
    fn optimization_preserves_numerics_all_levels() {
        // The cornerstone guarantee: every arm computes the same function.
        let g = {
            let mut b = crate::graph::GraphBuilder::new("t");
            let x = b.input("x", crate::graph::Shape::nchw(1, 8, 16, 16));
            let y1 = b.conv_bn_relu("b1", x, 16, 3, 1, 1);
            let p = b.avgpool("p", y1, 2, 2);
            let y2 = b.conv_bn_relu("b2", p, 32, 1, 1, 0);
            let gp = b.global_pool("gp", y2);
            let fc = b.fc("fc", gp, 4);
            b.output(fc);
            b.finish()
        };
        let d = presets::tms320c6678();
        let base = Interpreter::new(&g).run_synthetic(17);
        for level in [OptLevel::Vanilla, OptLevel::HoOnly, OptLevel::Full] {
            let o = optimize(&g, &d, OptimizeOptions { level, search: false });
            let out = Interpreter::new(&o.graph).run_synthetic(17);
            assert_eq!(base[0].data, out[0].data, "{level:?} changed numerics");
        }
    }

    #[test]
    fn auto_optimization_is_subsecond_for_all_benchmarks() {
        // Paper Table 2: 0.11-0.91s on their workstation; our graphs are
        // comparable sizes and the pass must stay well under a second.
        let d = presets::tms320c6678();
        for name in models::PAPER_BENCHMARKS {
            let g = models::by_name(name).unwrap();
            let o = auto(&g, &d);
            assert!(
                o.elapsed.as_secs_f64() < 1.0,
                "{name} took {:?}",
                o.elapsed
            );
        }
    }
}
