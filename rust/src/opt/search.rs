//! Cost-guided layout search — the paper's §8 future-work item
//! ("the automatic search algorithm from TASO/PET can also be inherited by
//! Xenos to discover more optimized schemes"), implemented as an *optional*
//! refinement pass.
//!
//! The heuristic linking pass resolves each producer's layout from its
//! consumers' declared preferences and leaves conflicted producers at their
//! natural write order. This pass revisits exactly those decision points
//! and scores each candidate layout with the simulator's cost model over
//! the producer's neighbourhood (producer + all consumers) — a bounded,
//! cost-function-driven search in the TASO/PET style, but anchored on the
//! dataflow decision variables Xenos exposes, so the space stays linear in
//! graph size instead of exponential in operator count.

use crate::graph::{DataLayout, Graph, NodeId, OpKind};
use crate::hw::DeviceModel;
use crate::obs::profile::CostSource;
use crate::opt::plan::{ExecutionPlan, OptLevel};
use crate::opt::{dos, linking::LinkRecord};
use crate::sim::cost::node_total_src;

/// One search refinement applied on top of the heuristic linking.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchRecord {
    /// Producer whose layout was changed.
    pub producer: String,
    /// Layout chosen by the heuristic pass.
    pub heuristic: DataLayout,
    /// Layout chosen by the cost-guided search.
    pub chosen: DataLayout,
    /// Predicted neighbourhood time before/after (seconds).
    pub before_s: f64,
    /// Predicted time after.
    pub after_s: f64,
}

/// Neighbourhood cost of `producer` under the current graph layouts: the
/// producer's own cost plus every consumer's cost.
fn neighbourhood_cost(
    g: &Graph,
    plan: &ExecutionPlan,
    device: &DeviceModel,
    producer: NodeId,
    consumers: &[NodeId],
    source: &CostSource,
) -> f64 {
    let mut t = node_total_src(g, g.node(producer), plan.node(producer), device, source);
    for &c in consumers {
        t += node_total_src(g, g.node(c), plan.node(c), device, source);
    }
    t
}

/// Candidate layouts for a feature-map producer.
fn candidates(g: &Graph, id: NodeId) -> Vec<DataLayout> {
    let n = g.node(id);
    if !n.out.shape.is_fm() {
        return vec![DataLayout::RowMajor, DataLayout::ColMajor];
    }
    let mut c = vec![DataLayout::Chw, DataLayout::Hwc];
    // Window-linked layouts only make sense if some consumer pools.
    for &cons in &g.consumers()[id] {
        if let OpKind::Pool(p) = g.node(cons).op {
            if p.k > 0 {
                c.push(DataLayout::Linked { ph: p.k as u8, pw: p.k as u8 });
            }
        }
    }
    c
}

/// Refine a linked graph's layout decisions with the cost model. Mutates
/// `g` in place and returns the improvements applied.
pub fn refine_layouts(g: &mut Graph, device: &DeviceModel) -> Vec<SearchRecord> {
    refine_layouts_src(g, device, &CostSource::Analytic)
}

/// [`refine_layouts`] scoring neighbourhoods through an explicit
/// [`CostSource`] — with `CostSource::Measured` the search optimizes
/// layouts against profiled op times (`--measured-costs`) instead of the
/// analytic model alone.
pub fn refine_layouts_src(
    g: &mut Graph,
    device: &DeviceModel,
    source: &CostSource,
) -> Vec<SearchRecord> {
    let consumers = g.consumers();
    let mut records = Vec::new();
    for id in 0..g.len() {
        if matches!(g.node(id).op, OpKind::Input) || consumers[id].is_empty() {
            continue;
        }
        let current = g.node(id).out.layout;
        let mut best = current;
        // Plans are layout-independent; compute once per candidate set.
        let plan = dos::plan_graph(g, device, OptLevel::Full);
        let mut best_t = neighbourhood_cost(g, &plan, device, id, &consumers[id], source);
        let before_t = best_t;
        for cand in candidates(g, id) {
            if cand == current {
                continue;
            }
            g.node_mut(id).out.layout = cand;
            let plan = dos::plan_graph(g, device, OptLevel::Full);
            let t = neighbourhood_cost(g, &plan, device, id, &consumers[id], source);
            if t < best_t {
                best_t = t;
                best = cand;
            }
        }
        g.node_mut(id).out.layout = best;
        if best != current {
            records.push(SearchRecord {
                producer: g.node(id).name.clone(),
                heuristic: current,
                chosen: best,
                before_s: before_t,
                after_s: best_t,
            });
        }
    }
    records
}

/// Convert search records into the common link-record format for display.
pub fn as_link_records(records: &[SearchRecord]) -> Vec<LinkRecord> {
    records
        .iter()
        .map(|r| LinkRecord {
            pattern: "cost-guided refinement".to_string(),
            producer: r.producer.clone(),
            consumer: format!("{} -> {}", r.heuristic.tag(), r.chosen.tag()),
            layout: r.chosen,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Shape};
    use crate::hw::presets;
    use crate::ops::Interpreter;
    use crate::opt::{fusion, linking};
    use crate::sim::Simulator;

    /// A producer with *conflicting* consumer preferences: an avg-pool
    /// consumer (wants `Linked{2,2}`) and a pointwise-conv consumer (wants
    /// `Hwc`). The heuristic refuses to link (conflict → natural `Chw`,
    /// mismatching BOTH readers); the search picks whichever single layout
    /// satisfies the costlier reader.
    fn conflicted_graph() -> crate::graph::Graph {
        let mut b = GraphBuilder::new("conflict");
        let x = b.input("x", Shape::nchw(1, 64, 28, 28));
        let prod = b.conv("prod", x, 64, 3, 1, 1);
        let pool = b.avgpool("pool", prod, 2, 2);
        let pw = b.conv("pw", prod, 128, 1, 1, 0);
        let gp1 = b.global_pool("gp1", pool);
        let gp2 = b.global_pool("gp2", pw);
        let cat = b.concat("cat", &[gp1, gp2]);
        b.output(cat);
        b.finish()
    }

    #[test]
    fn search_resolves_conflicts_the_heuristic_skips() {
        let d = presets::tms320c6678();
        let (fused, _) = fusion::fuse_cbr(&conflicted_graph());
        let mut linked = linking::link(&fused).graph;
        // Heuristic leaves `prod` natural (conflicting prefs).
        let prod = linked.nodes.iter().find(|n| n.name == "prod").unwrap();
        assert_eq!(prod.out.layout, DataLayout::Chw);
        let records = refine_layouts(&mut linked, &d);
        assert!(
            records.iter().any(|r| r.producer == "prod"),
            "search should revisit the conflicted producer: {records:?}"
        );
        // Any single non-natural layout un-mismatches one reader.
        let prod = linked.nodes.iter().find(|n| n.name == "prod").unwrap();
        assert_ne!(prod.out.layout, DataLayout::Chw);
    }

    #[test]
    fn search_never_regresses_predicted_time() {
        let d = presets::tms320c6678();
        for model in ["mobilenet", "squeezenet", "shufflenet"] {
            let g = crate::graph::models::by_name(model).unwrap();
            let (fused, _) = fusion::fuse_cbr(&g);
            let mut linked = linking::link(&fused).graph;
            let sim = Simulator::new(d.clone());
            let before = sim
                .simulate(&linked, &dos::plan_graph(&linked, &d, OptLevel::Full))
                .total_s;
            refine_layouts(&mut linked, &d);
            let after = sim
                .simulate(&linked, &dos::plan_graph(&linked, &d, OptLevel::Full))
                .total_s;
            assert!(
                after <= before * 1.0001,
                "{model}: search regressed {before} -> {after}"
            );
        }
    }

    #[test]
    fn search_preserves_numerics() {
        let d = presets::tms320c6678();
        let g = conflicted_graph();
        let (fused, _) = fusion::fuse_cbr(&g);
        let mut linked = linking::link(&fused).graph;
        refine_layouts(&mut linked, &d);
        let a = Interpreter::new(&g).run_synthetic(33);
        let b = Interpreter::new(&linked).run_synthetic(33);
        assert_eq!(a[0].data, b[0].data, "layout search is metadata-only");
    }

    #[test]
    fn improvements_report_time_deltas() {
        let d = presets::tms320c6678();
        let (fused, _) = fusion::fuse_cbr(&conflicted_graph());
        let mut linked = linking::link(&fused).graph;
        for r in refine_layouts(&mut linked, &d) {
            assert!(r.after_s < r.before_s, "{r:?}");
        }
    }
}
