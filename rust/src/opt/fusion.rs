//! Operator fusion — the "basic optimization" Xenos runs during
//! preprocessing (paper §3: "as in typical frameworks (TASO and PET),
//! Xenos' optimization workflow conducts operator fusion during the
//! preprocessing stage"). Folds Conv→Bn→Relu chains into the `x.cbr`
//! fused operator; all Fig. 7 arms (including Vanilla) run on the fused
//! graph so the ablation isolates HO/VO.

use super::rewrite::Rewriter;
use crate::graph::{Graph, NodeId, OpKind};

/// Fuse every `Conv → BatchNorm → Relu` chain (each link single-consumer)
/// into a [`OpKind::Cbr`] node. Returns the rewritten graph and the number
/// of fusions performed.
pub fn fuse_cbr(g: &Graph) -> (Graph, usize) {
    let consumers = g.consumers();
    let single = |id: NodeId| consumers[id].len() == 1;

    // conv id -> (bn id, relu id)
    let mut fuse_at: std::collections::HashMap<NodeId, (NodeId, NodeId)> =
        std::collections::HashMap::new();
    let mut absorbed: std::collections::HashSet<NodeId> = std::collections::HashSet::new();

    for n in &g.nodes {
        if !matches!(n.op, OpKind::Conv(_)) || !single(n.id) {
            continue;
        }
        let bn = consumers[n.id][0];
        if !matches!(g.node(bn).op, OpKind::BatchNorm) || !single(bn) {
            continue;
        }
        let relu = consumers[bn][0];
        if !matches!(g.node(relu).op, OpKind::Relu) {
            continue;
        }
        fuse_at.insert(n.id, (bn, relu));
        absorbed.insert(bn);
        absorbed.insert(relu);
    }

    let mut rw = Rewriter::new(g);
    let mut count = 0;
    for n in &g.nodes {
        if absorbed.contains(&n.id) {
            continue; // already merged into its conv
        }
        if let Some(&(bn, relu)) = fuse_at.get(&n.id) {
            let attrs = *n.op.conv_attrs().expect("fusion root is a conv");
            // Fused node keeps the conv's name with the `/conv` suffix
            // stripped, matching the `conv_bn_relu` builder idiom.
            let name = n.name.strip_suffix("/conv").unwrap_or(&n.name).to_string();
            rw.emit_merged(
                g,
                &[n.id, bn, relu],
                &name,
                OpKind::Cbr(attrs),
                &n.inputs,
                g.node(relu).out.clone(),
            );
            count += 1;
        } else {
            rw.copy(g, n.id);
        }
    }
    (rw.finish(g), count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{models, GraphBuilder, Shape};
    use crate::ops::Interpreter;

    #[test]
    fn fuses_simple_chain() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", Shape::nchw(1, 3, 8, 8));
        let y = b.conv_bn_relu("blk", x, 8, 3, 1, 1);
        b.output(y);
        let g = b.finish();
        let (f, n) = fuse_cbr(&g);
        assert_eq!(n, 1);
        assert_eq!(f.len(), 2); // input + cbr
        assert!(matches!(f.node(1).op, OpKind::Cbr(_)));
        assert_eq!(f.node(1).name, "blk");
        assert_eq!(
            f.node(1).fused_from,
            vec!["blk/conv".to_string(), "blk/bn".to_string(), "blk/relu".to_string()]
        );
    }

    #[test]
    fn skips_conv_with_two_consumers() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", Shape::nchw(1, 3, 8, 8));
        let c = b.conv("c", x, 8, 3, 1, 1);
        let bn = b.bn("bn", c);
        let r = b.relu("r", bn);
        let s = b.sigmoid("s", c); // second consumer of conv
        b.output(r);
        b.output(s);
        let g = b.finish();
        let (f, n) = fuse_cbr(&g);
        assert_eq!(n, 0);
        assert_eq!(f.len(), g.len());
    }

    #[test]
    fn fusion_preserves_numerics_exactly() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", Shape::nchw(1, 3, 12, 12));
        let y1 = b.conv_bn_relu("b1", x, 8, 3, 2, 1);
        let y2 = b.conv_bn_relu("b2", y1, 16, 1, 1, 0);
        let gp = b.global_pool("gp", y2);
        let fc = b.fc("fc", gp, 5);
        b.output(fc);
        let g = b.finish();
        let (f, n) = fuse_cbr(&g);
        assert_eq!(n, 2);
        let a = Interpreter::new(&g).run_synthetic(11);
        let bres = Interpreter::new(&f).run_synthetic(11);
        assert_eq!(a[0].data, bres[0].data, "fusion must be bit-exact");
    }

    #[test]
    fn mobilenet_fuses_all_27_triples() {
        let g = models::mobilenet();
        let (f, n) = fuse_cbr(&g);
        // stem + 13 blocks x 2 convs = 27 CBR triples.
        assert_eq!(n, 27);
        assert_eq!(f.len(), g.len() - 2 * 27);
        f.validate().unwrap();
    }

    #[test]
    fn resnet18_fusion_keeps_shortcuts_valid() {
        let g = models::resnet18();
        let (f, _) = fuse_cbr(&g);
        f.validate().unwrap();
        let a = g.total_macs();
        let b = f.total_macs();
        // MAC count must be preserved by fusion (Cbr counts conv macs;
        // bn/relu macs are folded, so allow a small decrease).
        assert!(b <= a && b > a * 9 / 10, "{b} vs {a}");
    }
}
