//! Vertical dataflow optimization — **operator linking** (paper §4.1).
//!
//! Two mechanisms, both purely dataflow-level (no new operator kinds are
//! invented, per the paper's §6.1 maintenance argument — `x.cbra`/`x.cbrm`
//! already exist in the operator library):
//!
//! 1. **Linked-operator formation.** A `CBR → {Avg,Max}Pool` pair with a
//!    non-overlapping window (k == stride) and a single consumer is merged
//!    into the `x.cbra`/`x.cbrm` linked operator, which computes the conv
//!    and reduces each pooling window while it is still resident — the
//!    paper's Figure 4/5 optimization.
//! 2. **Layout linking.** For every remaining producer→consumer edge where
//!    the consumer's read order differs from the producer's write order,
//!    the producer's output-layout *metadata* is rewritten to the
//!    consumer's preference (the paper's "modify the metadata to change the
//!    dataflow between these adjacent operators").
//!
//! The pass also reports which Table-1 pattern each link instantiates.

use super::rewrite::Rewriter;
use crate::graph::{DataLayout, Graph, NodeId, OpKind, PoolKind};

/// A record of one applied link, for Table-1 style reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkRecord {
    /// Which Table-1 pattern family the link instantiates.
    pub pattern: String,
    /// Producer node name (in the linked graph).
    pub producer: String,
    /// Consumer node name.
    pub consumer: String,
    /// Layout the producer now writes.
    pub layout: DataLayout,
}

/// Result of the linking pass.
#[derive(Debug)]
pub struct Linked {
    /// The rewritten graph (merged linked ops + layout metadata).
    pub graph: Graph,
    /// Applied links.
    pub records: Vec<LinkRecord>,
}

/// Classify a producer/consumer pair into its Table-1 pattern family.
fn pattern_name(prod: &OpKind, cons: &OpKind) -> String {
    let is_convish =
        |o: &OpKind| matches!(o, OpKind::Conv(_) | OpKind::Cbr(_) | OpKind::Cbra(..) | OpKind::Cbrm(..));
    match (prod, cons) {
        (p, OpKind::Pool(_)) if is_convish(p) => "ConvX -> ZPooling".to_string(),
        (p, c) if is_convish(p) && is_convish(c) => "ConvX -> ConvY".to_string(),
        (OpKind::Pool(_), c) if is_convish(c) => "ZPooling -> ConvY".to_string(),
        (OpKind::MatMul(_), OpKind::MatMul(_)) => "MatmulX -> MatmulY".to_string(),
        (OpKind::MatMul(_), OpKind::Transpose) => "MatmulX -> Transpose".to_string(),
        (p, c) => format!("{} -> {}", p.kind_name(), c.kind_name()),
    }
}

/// Step 1: merge `CBR → Pool(k==stride)` single-consumer pairs into
/// `Cbra`/`Cbrm` linked operators.
fn merge_cbr_pool(g: &Graph) -> (Graph, Vec<LinkRecord>) {
    let consumers = g.consumers();
    let mut merge_at: std::collections::HashMap<NodeId, NodeId> = std::collections::HashMap::new();
    let mut absorbed: std::collections::HashSet<NodeId> = std::collections::HashSet::new();

    for n in &g.nodes {
        let OpKind::Cbr(_) = n.op else { continue };
        if consumers[n.id].len() != 1 {
            continue;
        }
        let pool_id = consumers[n.id][0];
        let OpKind::Pool(p) = g.node(pool_id).op else { continue };
        // Only non-overlapping windows link cleanly (no cross-window reuse).
        if matches!(p.kind, PoolKind::Global) || p.k != p.stride {
            continue;
        }
        merge_at.insert(n.id, pool_id);
        absorbed.insert(pool_id);
    }

    let mut records = Vec::new();
    let mut rw = Rewriter::new(g);
    for n in &g.nodes {
        if absorbed.contains(&n.id) {
            continue;
        }
        if let Some(&pool_id) = merge_at.get(&n.id) {
            let OpKind::Cbr(attrs) = n.op else { unreachable!() };
            let OpKind::Pool(p) = g.node(pool_id).op else { unreachable!() };
            let op = match p.kind {
                PoolKind::Avg => OpKind::Cbra(attrs, p),
                PoolKind::Max => OpKind::Cbrm(attrs, p),
                PoolKind::Global => unreachable!(),
            };
            let mut out = g.node(pool_id).out.clone();
            // The linked operator writes pooling-window order internally.
            out.layout = DataLayout::Chw;
            let id = rw.emit_merged(g, &[n.id, pool_id], &n.name, op, &n.inputs, out);
            records.push(LinkRecord {
                pattern: "ConvX -> ConvY -> ZPooling".to_string(),
                producer: n.name.clone(),
                consumer: g.node(pool_id).name.clone(),
                layout: DataLayout::Linked { ph: p.k as u8, pw: p.k as u8 },
            });
            let _ = id;
        } else {
            rw.copy(g, n.id);
        }
    }
    (rw.finish(g), records)
}

/// Step 2: rewrite producer output layouts to their consumer's read order.
///
/// A producer is linked when every consumer that expresses a preference for
/// the producer's value agrees on the layout (conflicting preferences keep
/// the natural write order — the paper resolves those cases by majority in
/// its metadata pass; with disagreement the safe default wins).
fn link_layouts(g: &mut Graph) -> Vec<LinkRecord> {
    let consumers = g.consumers();
    let mut records = Vec::new();
    for id in 0..g.len() {
        let node = g.node(id);
        if matches!(node.op, OpKind::Input) {
            continue;
        }
        let natural = node.op.natural_write(&node.out);
        let mut prefs: Vec<(NodeId, DataLayout)> = Vec::new();
        let mut conflict = false;
        for &c in &consumers[id] {
            let cons = g.node(c);
            for (slot, &inp) in cons.inputs.iter().enumerate() {
                if inp != id {
                    continue;
                }
                if let Some(p) = cons.op.read_pref(slot, &node.out) {
                    if p != natural {
                        if let Some((_, prev)) = prefs.first() {
                            if *prev != p {
                                conflict = true;
                            }
                        }
                        prefs.push((c, p));
                    }
                }
            }
        }
        if conflict || prefs.is_empty() {
            continue;
        }
        let (consumer_id, layout) = prefs[0];
        let (prod_op, cons_op) =
            (g.node(id).op.clone(), g.node(consumer_id).op.clone());
        records.push(LinkRecord {
            pattern: pattern_name(&prod_op, &cons_op),
            producer: g.node(id).name.clone(),
            consumer: g.node(consumer_id).name.clone(),
            layout,
        });
        g.node_mut(id).out.layout = layout;
    }
    records
}

/// Run the full vertical-optimization pass.
pub fn link(g: &Graph) -> Linked {
    let (mut merged, mut records) = merge_cbr_pool(g);
    records.extend(link_layouts(&mut merged));
    Linked { graph: merged, records }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{models, GraphBuilder, Shape};
    use crate::opt::fusion::fuse_cbr;
    use crate::ops::Interpreter;

    fn cbr_pool_graph() -> Graph {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", Shape::nchw(1, 8, 8, 8));
        let y = b.conv_bn_relu("blk", x, 16, 1, 1, 0);
        let p = b.avgpool("pool", y, 2, 2);
        let gp = b.global_pool("gp", p);
        b.output(gp);
        b.finish()
    }

    #[test]
    fn merges_cbr_avgpool_into_cbra() {
        let (fused, _) = fuse_cbr(&cbr_pool_graph());
        let linked = link(&fused);
        assert!(linked
            .graph
            .nodes
            .iter()
            .any(|n| matches!(n.op, OpKind::Cbra(..))));
        assert!(linked
            .records
            .iter()
            .any(|r| r.pattern == "ConvX -> ConvY -> ZPooling"));
        linked.graph.validate().unwrap();
    }

    #[test]
    fn linked_graph_is_numerically_identical() {
        let g = cbr_pool_graph();
        let (fused, _) = fuse_cbr(&g);
        let linked = link(&fused);
        let a = Interpreter::new(&g).run_synthetic(3);
        let b = Interpreter::new(&linked.graph).run_synthetic(3);
        assert_eq!(a[0].data, b[0].data);
    }

    #[test]
    fn overlapping_pool_not_merged() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", Shape::nchw(1, 4, 8, 8));
        let y = b.conv_bn_relu("blk", x, 8, 1, 1, 0);
        let p = b.maxpool("pool", y, 3, 1); // overlapping
        b.output(p);
        let (fused, _) = fuse_cbr(&b.finish());
        let linked = link(&fused);
        assert!(!linked.graph.nodes.iter().any(|n| matches!(n.op, OpKind::Cbrm(..))));
    }

    #[test]
    fn dw_to_pw_edge_gets_hwc_layout() {
        // The paper's Figure 2: depthwise writes CHW, pointwise reads HWC.
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", Shape::nchw(1, 8, 8, 8));
        let dw = b.dwconv("dw", x, 3, 1, 1);
        let pw = b.conv("pw", dw, 16, 1, 1, 0);
        b.output(pw);
        let linked = link(&b.finish());
        let dw_node = linked.graph.nodes.iter().find(|n| n.name == "dw").unwrap();
        assert_eq!(dw_node.out.layout, DataLayout::Hwc);
        assert!(linked.records.iter().any(|r| r.pattern == "ConvX -> ConvY"));
    }

    #[test]
    fn matmul_chain_links_colmajor() {
        let mut b = GraphBuilder::new("t");
        let q = b.input("q", Shape::mat(16, 8));
        let k = b.input("k", Shape::mat(16, 8));
        let kt = b.transpose("kt", k);
        let s = b.matmul("s", q, kt); // kt is operand 1 -> ColMajor pref
        b.output(s);
        let linked = link(&b.finish());
        let kt_node = linked.graph.nodes.iter().find(|n| n.name == "kt").unwrap();
        assert_eq!(kt_node.out.layout, DataLayout::ColMajor);
    }

    #[test]
    fn conflicting_consumers_keep_natural_layout() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", Shape::nchw(1, 8, 8, 8));
        let c = b.conv("c", x, 8, 3, 1, 1);
        let dw = b.dwconv("dw", c, 3, 1, 1); // prefers Chw (natural, no link)
        let pw = b.conv("pw", c, 16, 1, 1, 0); // prefers Hwc
        let cat = b.concat("cat", &[dw, pw]);
        b.output(cat);
        let linked = link(&b.finish());
        let c_node = linked.graph.nodes.iter().find(|n| n.name == "c").unwrap();
        // dw's pref equals natural (Chw) so only pw expresses a non-natural
        // pref -> producer links to Hwc.
        assert_eq!(c_node.out.layout, DataLayout::Hwc);
    }

    #[test]
    fn mobilenet_links_every_ds_block() {
        let (fused, _) = fuse_cbr(&models::mobilenet());
        let linked = link(&fused);
        // 13 dw->pw links + 12 pw->dw links (Chw pref = natural, no record)
        // + final CBR... at minimum the 13 Figure-2 pairs must link.
        let conv_links = linked
            .records
            .iter()
            .filter(|r| r.pattern == "ConvX -> ConvY")
            .count();
        assert!(conv_links >= 13, "got {conv_links}");
        // Equivalence after the full pipeline.
        linked.graph.validate().unwrap();
    }

    #[test]
    fn squeezenet_linking_preserves_numerics() {
        // Fire modules: squeeze feeds two consumers with the same pref
        // (both dense convs want Hwc) -> links; must stay bit-identical.
        let g = models::squeezenet();
        let (fused, _) = fuse_cbr(&g);
        let linked = link(&fused);
        let sq = linked
            .graph
            .nodes
            .iter()
            .find(|n| n.name == "fire2/squeeze1x1")
            .unwrap();
        assert_eq!(sq.out.layout, DataLayout::Hwc);
        linked.graph.validate().unwrap();
    }
}
