//! Quantized execution: the per-node INT8 executor shared by every
//! engine, and [`QuantEngine`] — the single-host engine behind
//! `serve --precision int8 --engine interp|par`.
//!
//! [`qexec_node`] is the quantized counterpart of `ops::interp::
//! exec_node`: the single source of truth for what one operator computes
//! under INT8. The serial engine, the worker-pool engine and the d-Xenos
//! shard worker's replicated path all call it (or chunk the same tile
//! kernels it calls), so quantized output is bit-identical across all of
//! them — integer accumulation makes the chunking argument exact rather
//! than order-dependent.

use std::sync::Arc;

use anyhow::Result;

use super::calib::CalibTable;
use super::kernels;
use super::{quantize_slice, snap_slice, QWeights};
use crate::graph::{ConvAttrs, Graph, Node, NodeId, OpKind};
use crate::ops::elementwise as ew;
use crate::ops::interp::{exec_node, run_graph, synthetic_inputs};
use crate::ops::par_exec::chunks;
use crate::ops::params::{NodeParams, ParamStore};
use crate::ops::Tensor;
use crate::opt::dos::MIN_PARALLEL_ELEMS;
use crate::opt::quant::{plan_quant, QuantKind, QuantPlan};
use crate::runtime::pool::{ScopedJob, WorkerPool};

/// Everything an engine needs to execute one model at INT8: the precision
/// plan, the resolved per-node activation scales, and the quantized
/// weights. Built once per engine (or per cluster rank, from that rank's
/// weight shard — per-channel weight scales make shard-local quantization
/// identical to slicing the master's).
pub struct QuantRun {
    /// The precision assignment.
    pub plan: QuantPlan,
    /// Per-node activation scale, resolved through the plan's grid
    /// indirection (pass-through nodes carry their producer's scale).
    pub scales: Vec<f32>,
    /// Per-node quantized weights (empty for nodes without an integer
    /// kernel).
    qw: Vec<QWeights>,
}

impl QuantRun {
    /// Build a run from a calibration table and a per-node parameter
    /// accessor (`ParamStore::get_ref` for a full model,
    /// `ShardParams::get` for one rank's shard).
    pub fn build<'a>(
        g: &Graph,
        calib: &CalibTable,
        params: impl Fn(NodeId) -> &'a NodeParams,
    ) -> QuantRun {
        let plan = plan_quant(g);
        let mut scales = Vec::with_capacity(g.len());
        let mut qw = Vec::with_capacity(g.len());
        for n in &g.nodes {
            scales.push(calib.act_scale(plan.grid_of[n.id]));
            let prm = params(n.id);
            let w = match (&n.op, plan.kinds[n.id]) {
                (OpKind::Conv(a), QuantKind::IntDot)
                | (OpKind::Cbr(a), QuantKind::IntDot)
                | (OpKind::Cbra(a, _), QuantKind::IntDot)
                | (OpKind::Cbrm(a, _), QuantKind::IntDot) => {
                    let row = a.in_c_per_group() * a.kh * a.kw;
                    if prm.w.is_empty() {
                        QWeights::default()
                    } else {
                        QWeights::per_row(&prm.w, prm.w.len() / row, row)
                    }
                }
                (OpKind::MatMul(m), QuantKind::IntDot) if m.weighted => {
                    if prm.w.is_empty() {
                        QWeights::default()
                    } else {
                        QWeights::per_col(&prm.w, m.k, prm.w.len() / m.k)
                    }
                }
                _ => QWeights::default(),
            };
            qw.push(w);
        }
        QuantRun { plan, scales, qw }
    }

    /// Quantized weights of one node.
    pub(crate) fn qweights(&self, id: NodeId) -> &QWeights {
        &self.qw[id]
    }
}

/// Fused Bn+ReLU in place over a batch-1 feature map — the same
/// per-element expression as `ew::batchnorm` followed by `ew::relu` (and
/// as the cluster worker's `affine_relu_rect`), so every engine's CBR
/// epilogue is element-for-element identical.
pub(crate) fn bn_relu_inplace(t: &mut Tensor, scale: &[f32], shift: &[f32]) {
    let s = t.shape();
    let (n, c, h, w) = (s.n(), s.c(), s.h(), s.w());
    let hw = h * w;
    for b in 0..n {
        for ch in 0..c {
            let base = (b * c + ch) * hw;
            for v in &mut t.data[base..base + hw] {
                *v = ew::relu1(*v * scale[ch] + shift[ch]);
            }
        }
    }
}

/// Quantized convolution (+bias) of one conv-family node: quantize the
/// (grid-snapped) input exactly, run the integer kernel, requantize.
fn conv_int(run: &QuantRun, prm: &NodeParams, a: &ConvAttrs, node: &Node, x: &Tensor) -> Tensor {
    let sx = run.scales[node.inputs[0]];
    let s = x.shape();
    let qx = quantize_slice(&x.data, sx);
    kernels::conv2d_q8(
        &qx,
        s.n(),
        a.in_c,
        s.h(),
        s.w(),
        a,
        run.qweights(node.id),
        &prm.bias,
        sx,
    )
}

/// Execute one node at INT8 on concrete inputs — the quantized
/// counterpart of `exec_node`, shared by the serial engine, the parallel
/// engine's fallback and the cluster worker's replicated path.
pub(crate) fn qexec_node(
    run: &QuantRun,
    prm: &NodeParams,
    node: &Node,
    args: &[&Tensor],
) -> Tensor {
    let out_scale = run.scales[node.id];
    match run.plan.kinds[node.id] {
        QuantKind::Passthrough => exec_node(prm, &node.op, args),
        QuantKind::Requant => {
            let mut t = exec_node(prm, &node.op, args);
            snap_slice(&mut t.data, out_scale);
            t
        }
        QuantKind::IntDot => {
            let mut t = match &node.op {
                OpKind::Conv(a) => conv_int(run, prm, a, node, args[0]),
                OpKind::Cbr(a) => {
                    let mut c = conv_int(run, prm, a, node, args[0]);
                    bn_relu_inplace(&mut c, &prm.scale, &prm.shift);
                    c
                }
                OpKind::Cbra(a, pl) | OpKind::Cbrm(a, pl) => {
                    let mut c = conv_int(run, prm, a, node, args[0]);
                    bn_relu_inplace(&mut c, &prm.scale, &prm.shift);
                    crate::ops::pool::pool(&c, pl)
                }
                OpKind::MatMul(m) if m.weighted => {
                    let sx = run.scales[node.inputs[0]];
                    let rows = args[0].shape().numel() / m.k;
                    let qa = quantize_slice(&args[0].data, sx);
                    let data =
                        kernels::fc_q8(&qa, rows, m.k, m.n, run.qweights(node.id), &prm.bias, sx);
                    Tensor::new(node.out.clone(), data)
                }
                OpKind::MatMul(_) => {
                    let (sa, sb) = (run.scales[node.inputs[0]], run.scales[node.inputs[1]]);
                    let (m2, k) = (args[0].shape().dims[0], args[0].shape().dims[1]);
                    let n2 = args[1].shape().dims[1];
                    let qa = quantize_slice(&args[0].data, sa);
                    let qb = quantize_slice(&args[1].data, sb);
                    let data = kernels::matmul_q8(&qa, m2, k, &qb, n2, sa, sb);
                    Tensor::new(node.out.clone(), data)
                }
                other => unreachable!("IntDot on non-dot op {other:?}"),
            };
            snap_slice(&mut t.data, out_scale);
            t
        }
    }
}

/// Raw output pointer crossing into the worker pool; jobs write disjoint
/// regions only (same discipline as `ops::par_exec`).
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: only dereferenced on disjoint regions while the owning buffer
// is kept alive by the blocking `WorkerPool::run` call.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// The INT8 engine: serial when `workers == 1`, worker-pool-chunked
/// integer kernels otherwise. Chunking never changes a single output bit
/// (exact integer accumulation), so `serve --precision int8` answers
/// identically for `--engine interp` and `--engine par` at any thread
/// count.
pub struct QuantEngine {
    graph: Arc<Graph>,
    params: ParamStore,
    run: QuantRun,
    pool: Option<WorkerPool>,
    workers: usize,
}

impl QuantEngine {
    /// Build an engine for `graph` using `calib` for activation scales.
    pub fn new(graph: Arc<Graph>, calib: &CalibTable, workers: usize) -> Result<QuantEngine> {
        calib.matches(&graph)?;
        let params = ParamStore::for_graph(&graph);
        let run = QuantRun::build(&graph, calib, |id| params.get_ref(id));
        let workers = crate::ops::par_exec::clamp_workers(workers);
        let pool = if workers > 1 { Some(WorkerPool::new(workers)) } else { None };
        Ok(QuantEngine { graph, params, run, pool, workers })
    }

    /// The executed graph.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Effective worker count after clamping (1 = serial).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The precision plan in effect.
    pub fn plan(&self) -> &QuantPlan {
        &self.run.plan
    }

    /// Run one quantized inference. Inputs are snapped onto their
    /// calibrated grids at the graph edge (the inserted quantize node).
    pub fn run(&self, inputs: &[Tensor]) -> Vec<Tensor> {
        let ids = self.graph.input_ids();
        assert_eq!(inputs.len(), ids.len(), "graph {} input arity", self.graph.name);
        let snapped: Vec<Tensor> = inputs
            .iter()
            .zip(&ids)
            .map(|(t, &id)| {
                let mut t = t.clone();
                snap_slice(&mut t.data, self.run.scales[id]);
                t
            })
            .collect();
        run_graph(&self.graph, &snapped, |n, args| self.exec(n, args), |_| {})
    }

    /// Convenience: run on deterministic synthetic inputs from `seed`.
    pub fn run_synthetic(&self, seed: u64) -> Vec<Tensor> {
        self.run(&synthetic_inputs(&self.graph, seed))
    }

    fn exec(&self, node: &Node, args: &[&Tensor]) -> Tensor {
        let prm = self.params.get_ref(node.id);
        if self.pool.is_some()
            && self.run.plan.kinds[node.id] == QuantKind::IntDot
            && node.macs() >= MIN_PARALLEL_ELEMS as u64
        {
            if let Some(t) = self.exec_intdot_par(node, prm, args) {
                return t;
            }
        }
        qexec_node(&self.run, prm, node, args)
    }

    /// Pool-chunked integer kernels for the dot-product family. Returns
    /// `None` for shapes that must take the serial path.
    fn exec_intdot_par(&self, node: &Node, prm: &NodeParams, args: &[&Tensor]) -> Option<Tensor> {
        let out_scale = self.run.scales[node.id];
        let mut t = match &node.op {
            OpKind::Conv(a) => self.par_conv_int(node, prm, a, args[0])?,
            OpKind::Cbr(a) => {
                let mut c = self.par_conv_int(node, prm, a, args[0])?;
                bn_relu_inplace(&mut c, &prm.scale, &prm.shift);
                c
            }
            OpKind::Cbra(a, pl) | OpKind::Cbrm(a, pl) => {
                let mut c = self.par_conv_int(node, prm, a, args[0])?;
                bn_relu_inplace(&mut c, &prm.scale, &prm.shift);
                crate::ops::pool::pool(&c, pl)
            }
            OpKind::MatMul(m) if m.weighted => {
                let sx = self.run.scales[node.inputs[0]];
                let rows = args[0].shape().numel() / m.k;
                let qa = quantize_slice(&args[0].data, sx);
                let qw = self.run.qweights(node.id);
                let pool = self.pool.as_ref()?;
                let mut out = vec![0.0f32; rows * m.n];
                let ptr = SendPtr(out.as_mut_ptr());
                let (k, n) = (m.k, m.n);
                let sx_one = [sx];
                let qa_ref: &[i8] = &qa;
                let bias: &[f32] = &prm.bias;
                let mut jobs: Vec<ScopedJob<'_>> = Vec::new();
                for (j0, j1) in chunks(n, self.workers) {
                    jobs.push(Box::new(move || {
                        // SAFETY: disjoint column ranges of the same buffer.
                        unsafe {
                            kernels::matmul_panel_raw_q8(
                                qa_ref, rows, k, &qw.q, n, j0, j1, &sx_one, &qw.scale, &[],
                                bias, ptr.0,
                            )
                        };
                    }));
                }
                pool.run(jobs);
                Tensor::new(node.out.clone(), out)
            }
            OpKind::MatMul(_) => {
                let (sa, sb) = (self.run.scales[node.inputs[0]], self.run.scales[node.inputs[1]]);
                let (m2, k) = (args[0].shape().dims[0], args[0].shape().dims[1]);
                let n2 = args[1].shape().dims[1];
                let qa = quantize_slice(&args[0].data, sa);
                let qb = quantize_slice(&args[1].data, sb);
                let pool = self.pool.as_ref()?;
                let mut out = vec![0.0f32; m2 * n2];
                let ptr = SendPtr(out.as_mut_ptr());
                let (qa_ref, qb_ref): (&[i8], &[i8]) = (&qa, &qb);
                let (sa_one, sb_one) = ([sa], [sb]);
                let mut jobs: Vec<ScopedJob<'_>> = Vec::new();
                for (j0, j1) in chunks(n2, self.workers) {
                    jobs.push(Box::new(move || {
                        // SAFETY: disjoint column ranges of the same buffer.
                        unsafe {
                            kernels::matmul_panel_raw_q8(
                                qa_ref, m2, k, qb_ref, n2, j0, j1, &sa_one, &sb_one, &[], &[],
                                ptr.0,
                            )
                        };
                    }));
                }
                pool.run(jobs);
                Tensor::new(node.out.clone(), out)
            }
            _ => return None,
        };
        snap_slice(&mut t.data, out_scale);
        Some(t)
    }

    /// Pool-chunked quantized convolution (batch 1): output channels
    /// split across the workers, every chunk through the shared q8 tile
    /// kernels.
    fn par_conv_int(
        &self,
        node: &Node,
        prm: &NodeParams,
        a: &ConvAttrs,
        x: &Tensor,
    ) -> Option<Tensor> {
        let s = x.shape();
        if s.n() != 1 {
            return None;
        }
        let pool = self.pool.as_ref()?;
        let sx = self.run.scales[node.inputs[0]];
        let qx = quantize_slice(&x.data, sx);
        let (h, w) = (s.h(), s.w());
        let (oh, ow) = a.out_hw(h, w);
        let qw = self.run.qweights(node.id);
        let mut out = Tensor::zeros(crate::graph::TensorDesc::fm(1, a.out_c, oh, ow));
        let ptr = SendPtr(out.data.as_mut_ptr());
        let a2 = *a;
        let qx_ref: &[i8] = &qx;
        let bias: &[f32] = &prm.bias;
        let mut jobs: Vec<ScopedJob<'_>> = Vec::new();
        for (oc0, oc1) in chunks(a.out_c, self.workers) {
            jobs.push(Box::new(move || {
                // SAFETY: disjoint output-channel regions of the same buffer.
                unsafe {
                    kernels::conv2d_region_raw_q8(
                        qx_ref, a2.in_c, h, w, &a2, qw, bias, sx, oc0, oc1, 0, oh, 0, ow, oh,
                        ow, ptr.0,
                    )
                };
            }));
        }
        pool.run(jobs);
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Shape};

    fn cnn() -> Graph {
        let mut b = GraphBuilder::new("qexec_cnn");
        let x = b.input("x", Shape::nchw(1, 4, 16, 16));
        let c1 = b.conv_bn_relu("c1", x, 16, 3, 1, 1);
        let dw = b.dw_bn_relu("dw", c1, 3, 1, 1);
        let pw = b.conv_bn_relu("pw", dw, 32, 1, 1, 0);
        let p = b.avgpool("p", pw, 2, 2);
        let gp = b.global_pool("gp", p);
        let fc = b.fc("fc", gp, 10);
        let sm = b.softmax("sm", fc);
        b.output(sm);
        b.finish()
    }

    fn calib_for(g: &Graph) -> CalibTable {
        let p = ParamStore::for_graph(g);
        CalibTable::synthetic(g, &p, 4, 100)
    }

    #[test]
    fn parallel_engine_is_bit_identical_to_serial() {
        let g = Arc::new(cnn());
        let calib = calib_for(&g);
        let serial = QuantEngine::new(g.clone(), &calib, 1).unwrap();
        let want = serial.run_synthetic(5);
        for workers in [2usize, 4] {
            let par = QuantEngine::new(g.clone(), &calib, workers).unwrap();
            let got = par.run_synthetic(5);
            assert_eq!(want.len(), got.len());
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.data, b.data, "workers={workers} diverged");
            }
        }
    }

    #[test]
    fn quantized_output_tracks_f32_within_tolerance() {
        let g = Arc::new(cnn());
        let calib = calib_for(&g);
        let q = QuantEngine::new(g.clone(), &calib, 1).unwrap();
        let f = crate::ops::Interpreter::new(&g);
        let inputs = synthetic_inputs(&g, 6);
        let qo = q.run(&inputs);
        let fo = f.run(&inputs);
        // Softmax output: absolute tolerance on a [0, 1] distribution.
        let diff = fo[0].max_abs_diff(&qo[0]);
        assert!(diff < 0.15, "int8 drifted {diff} from f32");
        let sum: f32 = qo[0].data.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "snapped softmax sums to {sum}");
    }

    #[test]
    fn outputs_lie_on_their_grids() {
        let g = Arc::new(cnn());
        let calib = calib_for(&g);
        let q = QuantEngine::new(g.clone(), &calib, 1).unwrap();
        let out = q.run_synthetic(8);
        // The output node is Requant: every value must be k * scale.
        let scale = q.run.scales[*g.outputs.first().unwrap()];
        for &v in &out[0].data {
            let k = (v / scale).round();
            assert!((v - k * scale).abs() < 1e-6, "{v} off the {scale} grid");
        }
    }

    #[test]
    fn mismatched_calibration_is_rejected() {
        let g = Arc::new(cnn());
        let other = {
            let mut b = GraphBuilder::new("other");
            let x = b.input("x", Shape::nchw(1, 3, 8, 8));
            let c = b.conv("c", x, 4, 3, 1, 1);
            b.output(c);
            Arc::new(b.finish())
        };
        let calib = calib_for(&other);
        assert!(QuantEngine::new(g, &calib, 1).is_err());
    }

    #[test]
    fn matmul_attention_block_quantizes() {
        let mut b = GraphBuilder::new("qattn");
        let q = b.input("q", Shape::mat(16, 32));
        let k = b.input("k", Shape::mat(32, 16));
        let s = b.matmul("s", q, k);
        let sm = b.softmax("sm", s);
        b.output(sm);
        let g = Arc::new(b.finish());
        let calib = calib_for(&g);
        let qe = QuantEngine::new(g.clone(), &calib, 2).unwrap();
        let fe = crate::ops::Interpreter::new(&g);
        let inputs = synthetic_inputs(&g, 3);
        let diff = fe.run(&inputs)[0].max_abs_diff(&qe.run(&inputs)[0]);
        assert!(diff < 0.2, "attention int8 drifted {diff}");
    }
}
