//! Quantized execution: the per-node INT8 executor shared by every
//! engine, and [`QuantEngine`] — the single-host engine behind
//! `serve --precision int8 --engine interp|par`.
//!
//! `qexec_node` is the quantized counterpart of `ops::interp::
//! exec_node`: the single source of truth for what one operator computes
//! under INT8. The serial engine, the worker-pool engine and the d-Xenos
//! shard worker's replicated path all call it (or chunk the same tile
//! kernels it calls), so quantized output is bit-identical across all of
//! them — integer accumulation and the per-element fixed-point epilogue
//! make the chunking argument exact rather than order-dependent.
//!
//! **Integer-resident dataflow.** Activations travel between nodes as
//! [`QTensor`]s — i8 codes plus their grid. `IntDot` nodes consume codes
//! directly and emit codes through the fused requantize epilogue
//! (`RequantPlan`); f32 is materialized only at dequantize boundaries
//! (f32-computed operators, graph outputs). The engine counts any forced
//! i8→f32→i8 round-trip on an integer edge in
//! [`QuantRun::snap_roundtrips`]; the differential tests pin it at zero.

use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::calib::CalibTable;
use super::kernels::{self, DeqF32, Epilogue, FixedQ8, UNIT};
use super::{fix_bias, fix_multiplier, grid_scale, scale_for, QTensor, QWeights};
use crate::graph::{ConvAttrs, Graph, Node, NodeId, OpKind, Shape, TensorDesc};
use crate::ops::elementwise as ew;
use crate::ops::interp::{exec_node, synthetic_inputs};
use crate::ops::par_exec::chunks;
use crate::ops::params::{NodeParams, ParamStore};
use crate::ops::Tensor;
use crate::opt::dos::MIN_PARALLEL_ELEMS;
use crate::opt::quant::{plan_quant, QuantKind, QuantPlan};
use crate::runtime::pool::{ScopedJob, SendPtr, WorkerPool};

/// The precomputed fixed-point requantize epilogue of one `IntDot` node:
/// per-output-channel (or per-FC-column) multiplier, shift and bias on
/// the node's activation grid, with a fused ReLU realized as a zero
/// clamp. Folds input grid × weight scale × (optional BatchNorm affine)
/// ÷ output grid, so the kernel goes i32 accumulator → i8 code in pure
/// integer arithmetic.
pub(crate) struct RequantPlan {
    mult: Vec<i32>,
    shift: Vec<u8>,
    bias: Vec<i64>,
    lo: i8,
    by_col: bool,
}

impl RequantPlan {
    fn from_affine(eff: impl Iterator<Item = (f32, f32)>, lo: i8, by_col: bool) -> RequantPlan {
        let mut mult = Vec::new();
        let mut shift = Vec::new();
        let mut bias = Vec::new();
        for (es, eb) in eff {
            let (m, s) = fix_multiplier(es);
            mult.push(m);
            shift.push(s);
            bias.push(fix_bias(eb, s));
        }
        RequantPlan { mult, shift, bias, lo, by_col }
    }

    /// The kernel epilogue view.
    pub(crate) fn epilogue(&self) -> FixedQ8<'_> {
        FixedQ8 {
            mult: &self.mult,
            shift: &self.shift,
            bias: &self.bias,
            lo: self.lo,
            by_col: self.by_col,
        }
    }
}

/// Everything an engine needs to execute one model at INT8: the precision
/// plan, the resolved per-node activation grids, the (input-grid-folded)
/// quantized weights and the fixed-point requantize plans. Built once per
/// engine (or per cluster rank, from that rank's weight shard —
/// per-channel weight scales make shard-local quantization identical to
/// slicing the master's).
pub struct QuantRun {
    /// The precision assignment.
    pub plan: QuantPlan,
    /// Per-node activation grid: one scale (per-tensor) or one per
    /// feature-map channel. Pass-through nodes carry their producer's
    /// grid, remapped through channel-reordering ops.
    grids: Vec<Vec<f32>>,
    /// Per-node quantized weights (empty for nodes without an integer
    /// kernel). Input activation grids are folded into the weights before
    /// quantization, so `QWeights::scale` is the complete accumulator
    /// dequantization factor.
    qw: Vec<QWeights>,
    /// Per-node fixed-point requantize epilogues (IntDot nodes whose
    /// output is produced directly as codes; the pooled CBRA/CBRM links
    /// requantize after their f32 pool stage instead).
    rq: Vec<Option<RequantPlan>>,
    /// Forced i8→f32→i8 round-trips on integer edges — zero by
    /// construction; counted so the integer-dataflow tests can pin it.
    snap_roundtrips: AtomicU64,
}

/// Per-channel activation grid of one node from its calibrated ranges.
/// Feature maps with real spatial extent get one scale per channel
/// (dead-in-calibration channels inherit the tensor-wide scale so live
/// values still decode finely); single-pixel maps and non-fm tensors get
/// a per-tensor scale — a 1×1 "channel" is a single calibration sample,
/// far too tail-sensitive to pin a grid on.
fn calibrated_grid(calib: &CalibTable, n: &Node) -> Vec<f32> {
    let ranges = &calib.per_channel[n.id];
    let tensor_max = ranges.iter().fold(0.0f32, |m, v| m.max(*v));
    let s = &n.out.shape;
    if s.is_fm() && s.h() * s.w() > 1 && ranges.len() == s.c() && ranges.len() > 1 {
        ranges
            .iter()
            .map(|&r| {
                if r > 0.0 && r.is_finite() {
                    scale_for(r)
                } else {
                    scale_for(tensor_max)
                }
            })
            .collect()
    } else {
        vec![scale_for(tensor_max)]
    }
}

/// The grid a pass-through node's output lives on: its producer's,
/// remapped through channel-reordering selections.
fn derive_grid(op: &OpKind, src: &[f32]) -> Vec<f32> {
    if src.len() == 1 {
        return src.to_vec();
    }
    match op {
        OpKind::Slice { begin, end } => src[*begin..*end].to_vec(),
        OpKind::ChannelShuffle { groups } => {
            let c = src.len();
            let cpg = c / groups;
            // Same channel permutation as `shape_ops::shuffle_tile_raw`.
            (0..c).map(|dst| src[(dst % groups) * cpg + dst / groups]).collect()
        }
        OpKind::Relu | OpKind::Upsample { .. } | OpKind::Pool(_) => src.to_vec(),
        // Channel-axis-destroying pass-throughs (cannot occur on feature
        // maps today): fall back to the coarsest scale.
        _ => vec![src.iter().fold(0.0f32, |m, v| m.max(*v))],
    }
}

/// Fold a per-input-channel activation grid into conv weights before
/// quantization: `w'[oc, ic, k] = w[oc, ic, k] · grid[ic]`, so the
/// accumulator's dequantization factor collapses to the (folded) weight
/// scale alone. `off` is the global output channel of local row 0 —
/// OutC-sharded ranks fold with their slice's group mapping.
fn fold_conv_weights(
    w: &[f32],
    rows: usize,
    a: &ConvAttrs,
    off: usize,
    in_grid: &[f32],
) -> Vec<f32> {
    if in_grid.len() == 1 {
        let s = in_grid[0];
        return w.iter().map(|&v| v * s).collect();
    }
    debug_assert_eq!(in_grid.len(), a.in_c, "input grid does not match conv channels");
    let cpg_in = a.in_c_per_group();
    let cpg_out = a.out_c_per_group();
    let k = a.kh * a.kw;
    let mut out = Vec::with_capacity(w.len());
    for r in 0..rows {
        let g = (off + r) / cpg_out;
        for ic in 0..cpg_in {
            let s = in_grid[g * cpg_in + ic];
            let base = (r * cpg_in + ic) * k;
            out.extend(w[base..base + k].iter().map(|&v| v * s));
        }
    }
    out
}

/// Fold a (flattened feature-map) activation grid into FC weights:
/// element `kk` of the contraction axis belongs to channel `kk / (h·w)`
/// of the producer.
fn fold_fc_weights(w: &[f32], k: usize, n: usize, in_shape: &Shape, in_grid: &[f32]) -> Vec<f32> {
    if in_grid.len() == 1 {
        let s = in_grid[0];
        return w.iter().map(|&v| v * s).collect();
    }
    let hw = (in_shape.h() * in_shape.w()).max(1);
    let mut out = Vec::with_capacity(w.len());
    for kk in 0..k {
        let s = in_grid[(kk / hw).min(in_grid.len() - 1)];
        out.extend(w[kk * n..(kk + 1) * n].iter().map(|&v| v * s));
    }
    out
}

impl QuantRun {
    /// Build a run for a full (master) model from a calibration table and
    /// a per-node parameter accessor.
    pub fn build<'a>(
        g: &Graph,
        calib: &CalibTable,
        params: impl Fn(NodeId) -> &'a NodeParams,
    ) -> QuantRun {
        Self::build_with_offsets(g, calib, params, |_| 0)
    }

    /// As [`QuantRun::build`], for a weight shard: `row_offset` maps a
    /// node to the global output channel its local weight row 0
    /// corresponds to (0 for full/replicated nodes, the rank's channel
    /// share start for OutC-sharded conv nodes). The offset anchors both
    /// the per-channel input-grid fold and the output-grid slice, which
    /// is what keeps shard-local quantization identical to slicing the
    /// master's.
    pub fn build_with_offsets<'a>(
        g: &Graph,
        calib: &CalibTable,
        params: impl Fn(NodeId) -> &'a NodeParams,
        row_offset: impl Fn(NodeId) -> usize,
    ) -> QuantRun {
        let plan = plan_quant(g);
        // Activation grids first (topological: producers resolved).
        let mut grids: Vec<Vec<f32>> = Vec::with_capacity(g.len());
        for n in &g.nodes {
            let grid = if plan.kinds[n.id] == QuantKind::Passthrough {
                derive_grid(&n.op, &grids[n.inputs[0]])
            } else {
                calibrated_grid(calib, n)
            };
            grids.push(grid);
        }
        // Quantized weights (input grid folded in) + requantize plans.
        let mut qw: Vec<QWeights> = Vec::with_capacity(g.len());
        let mut rq: Vec<Option<RequantPlan>> = Vec::with_capacity(g.len());
        for n in &g.nodes {
            let prm = params(n.id);
            let (w, r) = match (&n.op, plan.kinds[n.id]) {
                (OpKind::Conv(a), QuantKind::IntDot)
                | (OpKind::Cbr(a), QuantKind::IntDot)
                | (OpKind::Cbra(a, _), QuantKind::IntDot)
                | (OpKind::Cbrm(a, _), QuantKind::IntDot) => {
                    let row = a.in_c_per_group() * a.kh * a.kw;
                    if prm.w.is_empty() {
                        (QWeights::default(), None)
                    } else {
                        let rows = prm.w.len() / row;
                        let off = row_offset(n.id);
                        let folded = fold_conv_weights(&prm.w, rows, a, off, &grids[n.inputs[0]]);
                        let w = QWeights::per_row(&folded, rows, row);
                        let r = conv_requant(&n.op, prm, &w, off, &grids[n.id]);
                        (w, r)
                    }
                }
                (OpKind::MatMul(m), QuantKind::IntDot) if m.weighted => {
                    if prm.w.is_empty() {
                        (QWeights::default(), None)
                    } else {
                        let cols = prm.w.len() / m.k;
                        let in_shape = &g.node(n.inputs[0]).out.shape;
                        let folded =
                            fold_fc_weights(&prm.w, m.k, cols, in_shape, &grids[n.inputs[0]]);
                        let w = QWeights::per_col(&folded, m.k, cols);
                        let s_out = grids[n.id][0];
                        let r = RequantPlan::from_affine(
                            (0..cols).map(|j| {
                                let b = if prm.bias.is_empty() { 0.0 } else { prm.bias[j] };
                                (w.scale[j] / s_out, b / s_out)
                            }),
                            -127,
                            true,
                        );
                        (w, Some(r))
                    }
                }
                (OpKind::MatMul(_), QuantKind::IntDot) => {
                    // Activation × activation: uniform fixed-point requant
                    // from the two (per-tensor) input grids.
                    let sa = grids[n.inputs[0]][0];
                    let sb = grids[n.inputs[1]][0];
                    let s_out = grids[n.id][0];
                    let r =
                        RequantPlan::from_affine(std::iter::once((sa * sb / s_out, 0.0)), -127, false);
                    (QWeights::default(), Some(r))
                }
                _ => (QWeights::default(), None),
            };
            qw.push(w);
            rq.push(r);
        }
        QuantRun { plan, grids, qw, rq, snap_roundtrips: AtomicU64::new(0) }
    }

    /// The activation grid of one node's output (len 1 = per-tensor).
    pub fn grid(&self, id: NodeId) -> &[f32] {
        &self.grids[id]
    }

    /// Quantized weights of one node.
    pub(crate) fn qweights(&self, id: NodeId) -> &QWeights {
        &self.qw[id]
    }

    /// Fixed-point requantize plan of one node, if it emits codes
    /// directly from the kernel.
    pub(crate) fn requant(&self, id: NodeId) -> Option<&RequantPlan> {
        self.rq[id].as_ref()
    }

    /// The f32 dequantize epilogue of a pooled CBRA/CBRM link: the folded
    /// weight scale on the row (output-channel) axis, unit columns, conv
    /// bias on the rows. Single-sourced so every engine's pooled-link
    /// convention stays identical.
    pub(crate) fn pool_link_epilogue<'a>(&'a self, id: NodeId, bias: &'a [f32]) -> DeqF32<'a> {
        DeqF32 {
            row_scale: &self.qw[id].scale,
            col_scale: &UNIT,
            row_bias: bias,
            col_bias: &[],
        }
    }

    /// Forced i8→f32→i8 round-trips on integer edges so far — zero on
    /// every supported graph (the end-to-end integer dataflow property).
    pub fn snap_roundtrips(&self) -> u64 {
        self.snap_roundtrips.load(Ordering::Relaxed)
    }

    /// Borrow one IntDot argument's codes. Arguments arrive i8-resident
    /// on the expected grid by construction; a grid mismatch forces a
    /// dequantize→requantize round-trip, which is counted.
    pub(crate) fn intdot_codes<'t>(&self, expect: NodeId, t: &'t QTensor) -> Cow<'t, [i8]> {
        if t.scale == self.grids[expect] {
            Cow::Borrowed(&t.data[..])
        } else {
            self.snap_roundtrips.fetch_add(1, Ordering::Relaxed);
            let f = t.dequantize();
            Cow::Owned(QTensor::quantize_with(&f, &self.grids[expect]).data)
        }
    }
}

/// The fixed-point requantize plan of a Conv/CBR node: fold the folded
/// weight scale, the (optional) BatchNorm affine and the output grid
/// into one per-output-channel multiplier. `off` is the global output
/// channel of local row 0 (shards).
fn conv_requant(
    op: &OpKind,
    prm: &NodeParams,
    w: &QWeights,
    off: usize,
    out_grid: &[f32],
) -> Option<RequantPlan> {
    let (fuse_bn, lo) = match op {
        OpKind::Conv(_) => (false, -127i8),
        OpKind::Cbr(_) => (true, 0i8),
        // CBRA/CBRM pool in f32 between the affine and the requantize —
        // they take the DeqF32 epilogue and quantize after the pool.
        _ => return None,
    };
    let rows = w.scale.len();
    let eff = (0..rows).map(|r| {
        let s_out = grid_scale(out_grid, off + r);
        let (bs, bsh) = if fuse_bn && !prm.scale.is_empty() {
            (prm.scale[r], prm.shift[r])
        } else {
            (1.0, 0.0)
        };
        let b0 = if prm.bias.is_empty() { 0.0 } else { prm.bias[r] };
        (w.scale[r] * bs / s_out, (b0 * bs + bsh) / s_out)
    });
    Some(RequantPlan::from_affine(eff, lo, false))
}

/// Fused Bn+ReLU in place over a batch-1 feature map — the same
/// per-element expression as `ew::batchnorm` followed by `ew::relu` (and
/// as the cluster worker's `affine_relu_rect`), so every engine's CBR
/// epilogue is element-for-element identical.
pub(crate) fn bn_relu_inplace(t: &mut Tensor, scale: &[f32], shift: &[f32]) {
    let s = t.shape();
    let (n, c, h, w) = (s.n(), s.c(), s.h(), s.w());
    let hw = h * w;
    for b in 0..n {
        for ch in 0..c {
            let base = (b * c + ch) * hw;
            for v in &mut t.data[base..base + hw] {
                *v = ew::relu1(*v * scale[ch] + shift[ch]);
            }
        }
    }
}

/// Execute one node at INT8 on i8-resident inputs — the quantized
/// counterpart of `exec_node`, shared by the serial engine, the parallel
/// engine's fallback and the cluster worker's replicated path. IntDot
/// nodes consume and produce codes; f32-computed nodes materialize f32
/// transiently and requantize onto their grid.
pub(crate) fn qexec_node(
    run: &QuantRun,
    prm: &NodeParams,
    node: &Node,
    args: &[&QTensor],
) -> QTensor {
    match run.plan.kinds[node.id] {
        QuantKind::Passthrough | QuantKind::Requant => {
            let f32_args: Vec<Tensor> = args.iter().map(|q| q.dequantize()).collect();
            let refs: Vec<&Tensor> = f32_args.iter().collect();
            let t = exec_node(prm, &node.op, &refs);
            QTensor::quantize_with(&t, run.grid(node.id))
        }
        QuantKind::IntDot => intdot_serial(run, prm, node, args),
    }
}

/// Serial IntDot execution: codes in, codes out.
fn intdot_serial(run: &QuantRun, prm: &NodeParams, node: &Node, args: &[&QTensor]) -> QTensor {
    let grid = run.grid(node.id).to_vec();
    match &node.op {
        OpKind::Conv(a) | OpKind::Cbr(a) => {
            let qx = run.intdot_codes(node.inputs[0], args[0]);
            let s = args[0].shape();
            let rq = run.requant(node.id).expect("conv requant plan");
            let data = kernels::conv2d_q8(
                &qx,
                s.n(),
                a.in_c,
                s.h(),
                s.w(),
                a,
                &run.qweights(node.id).q,
                &rq.epilogue(),
            );
            QTensor::from_codes(node.out.clone(), data, grid)
        }
        OpKind::Cbra(a, pl) | OpKind::Cbrm(a, pl) => {
            let qx = run.intdot_codes(node.inputs[0], args[0]);
            let s = args[0].shape();
            let qw = run.qweights(node.id);
            let ep = run.pool_link_epilogue(node.id, &prm.bias);
            let data = kernels::conv2d_q8(&qx, s.n(), a.in_c, s.h(), s.w(), a, &qw.q, &ep);
            let (oh, ow) = a.out_hw(s.h(), s.w());
            let mut c = Tensor::new(TensorDesc::fm(s.n(), a.out_c, oh, ow), data);
            bn_relu_inplace(&mut c, &prm.scale, &prm.shift);
            let p = crate::ops::pool::pool(&c, pl);
            QTensor::quantize_with(&p, &grid)
        }
        OpKind::MatMul(m) if m.weighted => {
            let qa = run.intdot_codes(node.inputs[0], args[0]);
            let rows = args[0].shape().numel() / m.k;
            let rq = run.requant(node.id).expect("fc requant plan");
            let data = kernels::fc_q8(
                &qa,
                rows,
                m.k,
                m.n,
                &run.qweights(node.id).q,
                &rq.epilogue(),
            );
            QTensor::from_codes(node.out.clone(), data, grid)
        }
        OpKind::MatMul(_) => {
            let qa = run.intdot_codes(node.inputs[0], args[0]);
            let qb = run.intdot_codes(node.inputs[1], args[1]);
            let (m2, k) = (args[0].shape().dims[0], args[0].shape().dims[1]);
            let n2 = args[1].shape().dims[1];
            let rq = run.requant(node.id).expect("matmul requant plan");
            let data = kernels::matmul_q8(&qa, m2, k, &qb, n2, &rq.epilogue());
            QTensor::from_codes(node.out.clone(), data, grid)
        }
        other => unreachable!("IntDot on non-dot op {other:?}"),
    }
}

/// The INT8 engine: serial when `workers == 1`, worker-pool-chunked
/// integer kernels otherwise. Chunking never changes a single output bit
/// (exact integer accumulation + per-element epilogue), so `serve
/// --precision int8` answers identically for `--engine interp` and
/// `--engine par` at any thread count.
pub struct QuantEngine {
    graph: Arc<Graph>,
    params: ParamStore,
    run: QuantRun,
    pool: Option<WorkerPool>,
    workers: usize,
}

impl QuantEngine {
    /// Build an engine for `graph` using `calib` for activation scales.
    pub fn new(graph: Arc<Graph>, calib: &CalibTable, workers: usize) -> Result<QuantEngine> {
        calib.matches(&graph)?;
        let params = ParamStore::for_graph(&graph);
        let run = QuantRun::build(&graph, calib, |id| params.get_ref(id));
        let workers = crate::ops::par_exec::clamp_workers(workers);
        let pool = if workers > 1 { Some(WorkerPool::new(workers)) } else { None };
        Ok(QuantEngine { graph, params, run, pool, workers })
    }

    /// The executed graph.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Effective worker count after clamping (1 = serial).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The precision plan in effect.
    pub fn plan(&self) -> &QuantPlan {
        &self.run.plan
    }

    /// Forced i8→f32→i8 round-trips on integer edges since construction
    /// — stays zero (the end-to-end integer dataflow property).
    pub fn snap_roundtrips(&self) -> u64 {
        self.run.snap_roundtrips()
    }

    /// Run one quantized inference. Inputs are quantized onto their
    /// calibrated grids at the graph edge (the inserted quantize node);
    /// every intermediate value stays i8-resident and outputs decode to
    /// f32 at the end.
    pub fn run(&self, inputs: &[Tensor]) -> Vec<Tensor> {
        let g = &*self.graph;
        let input_ids = g.input_ids();
        assert_eq!(inputs.len(), input_ids.len(), "graph {} input arity", g.name);
        // The same liveness walk as `ops::interp::run_graph`, over
        // i8-resident values.
        let mut uses: Vec<usize> = vec![0; g.len()];
        for n in &g.nodes {
            for &i in &n.inputs {
                uses[i] += 1;
            }
        }
        for &o in &g.outputs {
            uses[o] += 1;
        }
        let mut vals: Vec<Option<QTensor>> = (0..g.len()).map(|_| None).collect();
        let mut next_input = 0usize;
        for n in &g.nodes {
            let out = if matches!(n.op, OpKind::Input) {
                let t = &inputs[next_input];
                assert_eq!(t.shape(), &n.out.shape, "input {next_input} shape mismatch");
                next_input += 1;
                QTensor::quantize_with(t, self.run.grid(n.id))
            } else {
                let args: Vec<&QTensor> = n
                    .inputs
                    .iter()
                    .map(|&i| vals[i].as_ref().expect("input value live"))
                    .collect();
                // Same per-node compute span as `run_graph`; free when
                // recording is off.
                let _sp = crate::obs::trace::span(&n.name, crate::obs::trace::Cat::Compute);
                self.exec(n, &args)
            };
            vals[n.id] = Some(out);
            for &i in &n.inputs {
                uses[i] -= 1;
                if uses[i] == 0 && !g.outputs.contains(&i) {
                    vals[i] = None;
                }
            }
        }
        g.outputs
            .iter()
            .map(|&o| vals[o].as_ref().expect("output computed").dequantize())
            .collect()
    }

    /// Convenience: run on deterministic synthetic inputs from `seed`.
    pub fn run_synthetic(&self, seed: u64) -> Vec<Tensor> {
        self.run(&synthetic_inputs(&self.graph, seed))
    }

    /// Run one batch of quantized inferences in lockstep: element-wise
    /// identical to calling [`QuantEngine::run`] once per sample (exact
    /// integer accumulation makes every batched tiling bit-identical to
    /// the serial kernel), but FC weight panels are packed once per batch
    /// instead of once per sample and the worker pool is chunked over
    /// batch × output channels, so small nodes still fill every worker
    /// at batch 8. Returns `out[sample][output_idx]`.
    pub fn run_batch(&self, batch: &[Vec<Tensor>]) -> Vec<Vec<Tensor>> {
        let g = &*self.graph;
        let input_ids = g.input_ids();
        for (s, inputs) in batch.iter().enumerate() {
            assert_eq!(
                inputs.len(),
                input_ids.len(),
                "graph {} input arity (sample {s})",
                g.name
            );
        }
        let nbatch = batch.len();
        // The same liveness walk as `run`, over per-value sample vectors
        // kept in lockstep: every sample of a value dies at the same node.
        let mut uses: Vec<usize> = vec![0; g.len()];
        for n in &g.nodes {
            for &i in &n.inputs {
                uses[i] += 1;
            }
        }
        for &o in &g.outputs {
            uses[o] += 1;
        }
        let mut vals: Vec<Option<Vec<QTensor>>> = (0..g.len()).map(|_| None).collect();
        let mut next_input = 0usize;
        for n in &g.nodes {
            let out: Vec<QTensor> = if matches!(n.op, OpKind::Input) {
                let idx = next_input;
                next_input += 1;
                batch
                    .iter()
                    .map(|inputs| {
                        let t = &inputs[idx];
                        assert_eq!(t.shape(), &n.out.shape, "input {idx} shape mismatch");
                        QTensor::quantize_with(t, self.run.grid(n.id))
                    })
                    .collect()
            } else {
                let args: Vec<&[QTensor]> = n
                    .inputs
                    .iter()
                    .map(|&i| vals[i].as_deref().expect("input value live"))
                    .collect();
                let _sp = crate::obs::trace::span(&n.name, crate::obs::trace::Cat::Compute);
                self.exec_batch(n, &args)
            };
            debug_assert_eq!(out.len(), nbatch, "node {} batch arity", n.name);
            vals[n.id] = Some(out);
            for &i in &n.inputs {
                uses[i] -= 1;
                if uses[i] == 0 && !g.outputs.contains(&i) {
                    vals[i] = None;
                }
            }
        }
        (0..nbatch)
            .map(|s| {
                g.outputs
                    .iter()
                    .map(|&o| vals[o].as_ref().expect("output computed")[s].dequantize())
                    .collect()
            })
            .collect()
    }

    /// Execute one node for every sample of the batch. IntDot nodes big
    /// enough in aggregate (`macs × nbatch`) take the fused pool path;
    /// everything else runs the per-sample executor, which is already
    /// bit-identical across engines.
    fn exec_batch(&self, node: &Node, args: &[&[QTensor]]) -> Vec<QTensor> {
        let nbatch = args.first().map_or(0, |a| a.len());
        let prm = self.params.get_ref(node.id);
        if nbatch > 1
            && self.pool.is_some()
            && self.run.plan.kinds[node.id] == QuantKind::IntDot
            && node.macs().saturating_mul(nbatch as u64) >= MIN_PARALLEL_ELEMS as u64
        {
            if let Some(out) = self.exec_intdot_par_batch(node, prm, args) {
                return out;
            }
        }
        (0..nbatch)
            .map(|s| {
                let sargs: Vec<&QTensor> = args.iter().map(|a| &a[s]).collect();
                self.exec(node, &sargs)
            })
            .collect()
    }

    fn exec(&self, node: &Node, args: &[&QTensor]) -> QTensor {
        let prm = self.params.get_ref(node.id);
        if self.pool.is_some()
            && self.run.plan.kinds[node.id] == QuantKind::IntDot
            && node.macs() >= MIN_PARALLEL_ELEMS as u64
        {
            if let Some(t) = self.exec_intdot_par(node, prm, args) {
                return t;
            }
        }
        qexec_node(&self.run, prm, node, args)
    }

    /// Chunk one conv over the pool through the shared q8 region kernel
    /// with an arbitrary epilogue. Chunk boundaries never change a bit.
    #[allow(clippy::too_many_arguments)]
    fn par_conv_regions<E: Epilogue>(
        &self,
        a: &ConvAttrs,
        qx: &[i8],
        h: usize,
        w: usize,
        qwq: &[i8],
        ep: &E,
        out: *mut E::Out,
        oh: usize,
        ow: usize,
    ) {
        let pool = self.pool.as_ref().expect("parallel path");
        let ptr = SendPtr(out);
        let a2 = *a;
        let mut jobs: Vec<ScopedJob<'_>> = Vec::new();
        for (oc0, oc1) in chunks(a.out_c, self.workers) {
            jobs.push(Box::new(move || {
                // SAFETY: disjoint output-channel regions of the same buffer.
                unsafe {
                    kernels::conv2d_region_raw_q8(
                        qx, a2.in_c, h, w, &a2, qwq, ep, oc0, oc1, 0, oh, 0, ow, oh, ow, ptr.0,
                    )
                };
            }));
        }
        pool.run(jobs);
    }

    /// Pool-chunked integer kernels for the dot-product family. Returns
    /// `None` for shapes that must take the serial path.
    fn exec_intdot_par(&self, node: &Node, prm: &NodeParams, args: &[&QTensor]) -> Option<QTensor> {
        self.pool.as_ref()?;
        match &node.op {
            OpKind::Conv(a) | OpKind::Cbr(a) => {
                let s = args[0].shape();
                if s.n() != 1 {
                    return None;
                }
                let qx = self.run.intdot_codes(node.inputs[0], args[0]);
                let (h, w) = (s.h(), s.w());
                let (oh, ow) = a.out_hw(h, w);
                let rq = self.run.requant(node.id)?;
                let mut out = QTensor::zeros(node.out.clone(), self.run.grid(node.id).to_vec());
                let ep = rq.epilogue();
                self.par_conv_regions(
                    a,
                    &qx,
                    h,
                    w,
                    &self.run.qweights(node.id).q,
                    &ep,
                    out.data.as_mut_ptr(),
                    oh,
                    ow,
                );
                Some(out)
            }
            OpKind::Cbra(a, pl) | OpKind::Cbrm(a, pl) => {
                let s = args[0].shape();
                if s.n() != 1 {
                    return None;
                }
                let qx = self.run.intdot_codes(node.inputs[0], args[0]);
                let (h, w) = (s.h(), s.w());
                let (oh, ow) = a.out_hw(h, w);
                let qw = self.run.qweights(node.id);
                let ep = self.run.pool_link_epilogue(node.id, &prm.bias);
                let mut c = Tensor::zeros(TensorDesc::fm(1, a.out_c, oh, ow));
                self.par_conv_regions(a, &qx, h, w, &qw.q, &ep, c.data.as_mut_ptr(), oh, ow);
                bn_relu_inplace(&mut c, &prm.scale, &prm.shift);
                let p = crate::ops::pool::pool(&c, pl);
                Some(QTensor::quantize_with(&p, self.run.grid(node.id)))
            }
            OpKind::MatMul(m) if m.weighted => {
                let pool = self.pool.as_ref()?;
                let qa = self.run.intdot_codes(node.inputs[0], args[0]);
                let rows = args[0].shape().numel() / m.k;
                let rq = self.run.requant(node.id)?;
                let qw = self.run.qweights(node.id);
                let mut out = QTensor::zeros(node.out.clone(), self.run.grid(node.id).to_vec());
                let ptr = SendPtr(out.data.as_mut_ptr());
                let ep = rq.epilogue();
                let ep_ref = &ep;
                let (k, n) = (m.k, m.n);
                let qa_ref: &[i8] = &qa;
                let qwq: &[i8] = &qw.q;
                let mut jobs: Vec<ScopedJob<'_>> = Vec::new();
                for (j0, j1) in chunks(n, self.workers) {
                    jobs.push(Box::new(move || {
                        // SAFETY: disjoint column ranges of the same buffer.
                        unsafe {
                            kernels::matmul_panel_raw_q8(
                                qa_ref, rows, k, qwq, n, j0, j1, ep_ref, ptr.0,
                            )
                        };
                    }));
                }
                pool.run(jobs);
                Some(out)
            }
            OpKind::MatMul(_) => {
                let pool = self.pool.as_ref()?;
                let qa = self.run.intdot_codes(node.inputs[0], args[0]);
                let qb = self.run.intdot_codes(node.inputs[1], args[1]);
                let (m2, k) = (args[0].shape().dims[0], args[0].shape().dims[1]);
                let n2 = args[1].shape().dims[1];
                let rq = self.run.requant(node.id)?;
                let mut out = QTensor::zeros(node.out.clone(), self.run.grid(node.id).to_vec());
                let ptr = SendPtr(out.data.as_mut_ptr());
                let ep = rq.epilogue();
                let ep_ref = &ep;
                let (qa_ref, qb_ref): (&[i8], &[i8]) = (&qa, &qb);
                let mut jobs: Vec<ScopedJob<'_>> = Vec::new();
                for (j0, j1) in chunks(n2, self.workers) {
                    jobs.push(Box::new(move || {
                        // SAFETY: disjoint column ranges of the same buffer.
                        unsafe {
                            kernels::matmul_panel_raw_q8(
                                qa_ref, m2, k, qb_ref, n2, j0, j1, ep_ref, ptr.0,
                            )
                        };
                    }));
                }
                pool.run(jobs);
                Some(out)
            }
            _ => None,
        }
    }

    /// Batched pool-chunked integer kernels: all samples' chunk jobs go
    /// into ONE `pool.run`, with per-sample chunk counts scaled down by
    /// the batch size (`ceil(workers / nbatch)` ways) so the pool stays
    /// saturated without over-splitting. Integer accumulation is exact,
    /// so the fused tiling is bit-identical to the per-sample path.
    /// Returns `None` for shapes that must take the per-sample path.
    fn exec_intdot_par_batch(
        &self,
        node: &Node,
        prm: &NodeParams,
        args: &[&[QTensor]],
    ) -> Option<Vec<QTensor>> {
        let pool = self.pool.as_ref()?;
        let nbatch = args.first().map_or(0, |a| a.len());
        let ways = crate::util::ceil_div(self.workers, nbatch).max(1);
        match &node.op {
            OpKind::Conv(a) | OpKind::Cbr(a) => {
                let s = args[0][0].shape();
                if s.n() != 1 {
                    return None;
                }
                let rq = self.run.requant(node.id)?;
                let (h, w) = (s.h(), s.w());
                let (oh, ow) = a.out_hw(h, w);
                let codes: Vec<Cow<'_, [i8]>> = args[0]
                    .iter()
                    .map(|q| self.run.intdot_codes(node.inputs[0], q))
                    .collect();
                let grid = self.run.grid(node.id).to_vec();
                let mut outs: Vec<QTensor> =
                    (0..nbatch).map(|_| QTensor::zeros(node.out.clone(), grid.clone())).collect();
                let ptrs: Vec<SendPtr<i8>> =
                    outs.iter_mut().map(|o| SendPtr(o.data.as_mut_ptr())).collect();
                let ep = rq.epilogue();
                let ep_ref = &ep;
                let qwq: &[i8] = &self.run.qweights(node.id).q;
                let a2 = *a;
                let mut jobs: Vec<ScopedJob<'_>> = Vec::new();
                for (si, qx) in codes.iter().enumerate() {
                    let qx: &[i8] = qx;
                    let ptr = ptrs[si];
                    for (oc0, oc1) in chunks(a.out_c, ways) {
                        jobs.push(Box::new(move || {
                            // SAFETY: disjoint (sample, channel) regions.
                            unsafe {
                                kernels::conv2d_region_raw_q8(
                                    qx, a2.in_c, h, w, &a2, qwq, ep_ref, oc0, oc1, 0, oh, 0, ow,
                                    oh, ow, ptr.0,
                                )
                            };
                        }));
                    }
                }
                pool.run(jobs);
                Some(outs)
            }
            OpKind::Cbra(a, pl) | OpKind::Cbrm(a, pl) => {
                let s = args[0][0].shape();
                if s.n() != 1 {
                    return None;
                }
                let (h, w) = (s.h(), s.w());
                let (oh, ow) = a.out_hw(h, w);
                let qw = self.run.qweights(node.id);
                let ep = self.run.pool_link_epilogue(node.id, &prm.bias);
                let ep_ref = &ep;
                let codes: Vec<Cow<'_, [i8]>> = args[0]
                    .iter()
                    .map(|q| self.run.intdot_codes(node.inputs[0], q))
                    .collect();
                let mut convs: Vec<Tensor> = (0..nbatch)
                    .map(|_| Tensor::zeros(TensorDesc::fm(1, a.out_c, oh, ow)))
                    .collect();
                let ptrs: Vec<SendPtr<f32>> =
                    convs.iter_mut().map(|c| SendPtr(c.data.as_mut_ptr())).collect();
                let qwq: &[i8] = &qw.q;
                let a2 = *a;
                let mut jobs: Vec<ScopedJob<'_>> = Vec::new();
                for (si, qx) in codes.iter().enumerate() {
                    let qx: &[i8] = qx;
                    let ptr = ptrs[si];
                    for (oc0, oc1) in chunks(a.out_c, ways) {
                        jobs.push(Box::new(move || {
                            // SAFETY: disjoint (sample, channel) regions.
                            unsafe {
                                kernels::conv2d_region_raw_q8(
                                    qx, a2.in_c, h, w, &a2, qwq, ep_ref, oc0, oc1, 0, oh, 0, ow,
                                    oh, ow, ptr.0,
                                )
                            };
                        }));
                    }
                }
                pool.run(jobs);
                Some(
                    convs
                        .into_iter()
                        .map(|mut c| {
                            bn_relu_inplace(&mut c, &prm.scale, &prm.shift);
                            let p = crate::ops::pool::pool(&c, pl);
                            QTensor::quantize_with(&p, self.run.grid(node.id))
                        })
                        .collect(),
                )
            }
            OpKind::MatMul(m) if m.weighted => {
                let rq = self.run.requant(node.id)?;
                let rows = args[0][0].shape().numel() / m.k;
                let codes: Vec<Cow<'_, [i8]>> = args[0]
                    .iter()
                    .map(|q| self.run.intdot_codes(node.inputs[0], q))
                    .collect();
                let srcs: Vec<&[i8]> = codes.iter().map(|c| &c[..]).collect();
                let grid = self.run.grid(node.id).to_vec();
                let mut outs: Vec<QTensor> =
                    (0..nbatch).map(|_| QTensor::zeros(node.out.clone(), grid.clone())).collect();
                let ptrs: Vec<SendPtr<i8>> =
                    outs.iter_mut().map(|o| SendPtr(o.data.as_mut_ptr())).collect();
                let ep = rq.epilogue();
                let ep_ref = &ep;
                let qwq: &[i8] = &self.run.qweights(node.id).q;
                let (k, n) = (m.k, m.n);
                let mut jobs: Vec<ScopedJob<'_>> = Vec::new();
                // Column chunks across the full pool; each job sweeps the
                // whole batch so every weight panel is packed once per
                // batch instead of once per sample.
                for (j0, j1) in chunks(n, self.workers) {
                    let srcs = srcs.clone();
                    let ptrs = ptrs.clone();
                    jobs.push(Box::new(move || {
                        let raw: Vec<*mut i8> = ptrs.iter().map(|p| p.0).collect();
                        // SAFETY: disjoint column ranges per sample buffer.
                        unsafe {
                            kernels::matmul_panel_raw_q8_batch(
                                &srcs, rows, k, qwq, n, j0, j1, ep_ref, &raw,
                            )
                        };
                    }));
                }
                pool.run(jobs);
                Some(outs)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Shape};

    fn cnn() -> Graph {
        let mut b = GraphBuilder::new("qexec_cnn");
        let x = b.input("x", Shape::nchw(1, 4, 16, 16));
        let c1 = b.conv_bn_relu("c1", x, 16, 3, 1, 1);
        let dw = b.dw_bn_relu("dw", c1, 3, 1, 1);
        let pw = b.conv_bn_relu("pw", dw, 32, 1, 1, 0);
        let p = b.avgpool("p", pw, 2, 2);
        let gp = b.global_pool("gp", p);
        let fc = b.fc("fc", gp, 10);
        let sm = b.softmax("sm", fc);
        b.output(sm);
        b.finish()
    }

    fn calib_for(g: &Graph) -> CalibTable {
        let p = ParamStore::for_graph(g);
        CalibTable::synthetic(g, &p, 4, 100)
    }

    #[test]
    fn parallel_engine_is_bit_identical_to_serial() {
        let g = Arc::new(cnn());
        let calib = calib_for(&g);
        let serial = QuantEngine::new(g.clone(), &calib, 1).unwrap();
        let want = serial.run_synthetic(5);
        for workers in [2usize, 4] {
            let par = QuantEngine::new(g.clone(), &calib, workers).unwrap();
            let got = par.run_synthetic(5);
            assert_eq!(want.len(), got.len());
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.data, b.data, "workers={workers} diverged");
            }
        }
    }

    #[test]
    fn run_batch_is_bit_identical_to_per_sample_runs() {
        let g = Arc::new(cnn());
        let calib = calib_for(&g);
        for workers in [1usize, 4] {
            let e = QuantEngine::new(g.clone(), &calib, workers).unwrap();
            let batch: Vec<Vec<Tensor>> =
                (0..5u64).map(|s| synthetic_inputs(&g, 40 + s)).collect();
            let got = e.run_batch(&batch);
            assert_eq!(got.len(), batch.len());
            for (s, inputs) in batch.iter().enumerate() {
                let want = e.run(inputs);
                assert_eq!(want.len(), got[s].len());
                for (a, b) in want.iter().zip(&got[s]) {
                    assert_eq!(a.data, b.data, "workers={workers} sample={s} diverged");
                }
            }
        }
    }

    #[test]
    fn quantized_output_tracks_f32_within_tolerance() {
        let g = Arc::new(cnn());
        let calib = calib_for(&g);
        let q = QuantEngine::new(g.clone(), &calib, 1).unwrap();
        let f = crate::ops::Interpreter::new(&g);
        let inputs = synthetic_inputs(&g, 6);
        let qo = q.run(&inputs);
        let fo = f.run(&inputs);
        // Softmax output: absolute tolerance on a [0, 1] distribution.
        let diff = fo[0].max_abs_diff(&qo[0]);
        assert!(diff < 0.15, "int8 drifted {diff} from f32");
        let sum: f32 = qo[0].data.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "quantized softmax sums to {sum}");
    }

    #[test]
    fn outputs_lie_on_their_grids() {
        let g = Arc::new(cnn());
        let calib = calib_for(&g);
        let q = QuantEngine::new(g.clone(), &calib, 1).unwrap();
        let out = q.run_synthetic(8);
        // The output node is Requant on a per-tensor grid (softmax over a
        // matrix): every value must be k * scale.
        let grid = q.run.grid(*g.outputs.first().unwrap());
        assert_eq!(grid.len(), 1, "softmax output grid is per-tensor");
        let scale = grid[0];
        for &v in &out[0].data {
            let k = (v / scale).round();
            assert!((v - k * scale).abs() < 1e-6, "{v} off the {scale} grid");
        }
    }

    #[test]
    fn intdot_chains_run_with_zero_snap_roundtrips() {
        // Fused CBR family (the MobileNet-style hot path): conv -> dw ->
        // pw are adjacent IntDot nodes; their edges must carry codes
        // only. Both the serial and the pooled engine pin the counter at
        // zero while agreeing bit-for-bit.
        let (fused, nf) = crate::opt::fusion::fuse_cbr(&cnn());
        assert!(nf > 0, "fusion must produce CBR nodes");
        let g = Arc::new(fused);
        let calib = calib_for(&g);
        let mut want: Option<Vec<Tensor>> = None;
        for workers in [1usize, 4] {
            let e = QuantEngine::new(g.clone(), &calib, workers).unwrap();
            let got = e.run_synthetic(9);
            assert_eq!(
                e.snap_roundtrips(),
                0,
                "workers={workers}: integer edge materialized f32"
            );
            match &want {
                None => want = Some(got),
                Some(w) => {
                    for (a, b) in w.iter().zip(&got) {
                        assert_eq!(a.data, b.data, "workers={workers} diverged");
                    }
                }
            }
        }
    }

    #[test]
    fn per_channel_grids_cover_feature_maps_only() {
        let g = cnn();
        let calib = calib_for(&g);
        let params = ParamStore::for_graph(&g);
        let run = QuantRun::build(&g, &calib, |id| params.get_ref(id));
        let id_of = |name: &str| g.nodes.iter().find(|n| n.name == name).unwrap().id;
        // A conv feature map gets one scale per channel...
        assert_eq!(run.grid(id_of("c1/conv")).len(), 16);
        // ...and its ReLU (pass-through) inherits that grid verbatim.
        assert_eq!(run.grid(id_of("c1/relu")), run.grid(id_of("c1/bn")));
        // The 1x1 global-pool output and the FC matrix stay per-tensor.
        assert_eq!(run.grid(id_of("gp")).len(), 1);
        assert_eq!(run.grid(id_of("fc")).len(), 1);
    }

    #[test]
    fn mismatched_calibration_is_rejected() {
        let g = Arc::new(cnn());
        let other = {
            let mut b = GraphBuilder::new("other");
            let x = b.input("x", Shape::nchw(1, 3, 8, 8));
            let c = b.conv("c", x, 4, 3, 1, 1);
            b.output(c);
            Arc::new(b.finish())
        };
        let calib = calib_for(&other);
        assert!(QuantEngine::new(g, &calib, 1).is_err());
    }

    #[test]
    fn matmul_attention_block_quantizes() {
        let mut b = GraphBuilder::new("qattn");
        let q = b.input("q", Shape::mat(16, 32));
        let k = b.input("k", Shape::mat(32, 16));
        let s = b.matmul("s", q, k);
        let sm = b.softmax("sm", s);
        b.output(sm);
        let g = Arc::new(b.finish());
        let calib = calib_for(&g);
        let qe = QuantEngine::new(g.clone(), &calib, 2).unwrap();
        let fe = crate::ops::Interpreter::new(&g);
        let inputs = synthetic_inputs(&g, 3);
        let diff = fe.run(&inputs)[0].max_abs_diff(&qe.run(&inputs)[0]);
        assert!(diff < 0.2, "attention int8 drifted {diff}");
    }
}
