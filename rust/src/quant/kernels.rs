//! INT8 tile kernels with i32 accumulation, mirroring the f32 kernels in
//! `ops::conv` / `ops::matmul` tile-for-tile so the parallel executor's
//! (oc, oy) chunking, the pointwise fast path and the d-Xenos region
//! shards route identically at both precisions.
//!
//! Correctness note that makes quantized execution *easier* to
//! distribute than f32: the per-element reduction is an exact integer sum
//! (`i8 × i8 → i32`; worst case `127·127·k` stays far below `i32::MAX`
//! for every shape in the zoo), so **any** tiling or chunk order yields a
//! bit-identical accumulator, and the single `acc → f32` requantization
//! step is per-element. Parallel and sharded runs therefore match the
//! serial kernel without the careful shared-loop-order argument the f32
//! path needs.

use super::QWeights;
use crate::graph::{ConvAttrs, TensorDesc};
use crate::ops::conv::is_pointwise_fast_path;
use crate::ops::Tensor;

/// Register-tile width of the packed i8 panel (matches the f32 kernel).
const NR: usize = 8;
/// Register-tile height.
const MR: usize = 4;

/// Scale lookup that treats a length-1 slice as uniform.
#[inline]
fn sc(scales: &[f32], i: usize) -> f32 {
    if scales.len() == 1 {
        scales[0]
    } else {
        scales[i]
    }
}

/// Generic quantized conv tile: output channels `oc0..oc1`, rows
/// `oy0..oy1`, columns `tx0..tx1` of batch `b`, written (requantized to
/// f32) into the full `[n, out_c, oh, ow]` buffer behind `out`.
///
/// `qx` is the i8 input `[n, in_c, h, w]` at per-tensor scale `sx`; `qw`
/// the i8 weights in f32 layout with per-output-channel scales `sw`;
/// `bias` the f32 bias (empty = none). Each output element is
/// `acc_i32 · sx · sw[oc] + bias[oc]`.
///
/// # Safety
/// `out` must point at a live `n*out_c*oh*ow` f32 buffer. Concurrent
/// calls on the same buffer must target disjoint `(oc, oy, ox)` tiles.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn conv2d_tile_raw_q8(
    qx: &[i8],
    in_c: usize,
    h: usize,
    w: usize,
    attrs: &ConvAttrs,
    qw: &[i8],
    sw: &[f32],
    bias: &[f32],
    sx: f32,
    b: usize,
    oc0: usize,
    oc1: usize,
    oy0: usize,
    oy1: usize,
    tx0: usize,
    tx1: usize,
    oh: usize,
    ow: usize,
    out: *mut f32,
) {
    debug_assert_eq!(in_c, attrs.in_c, "q8 conv input channels");
    let cpg_in = attrs.in_c / attrs.groups;
    let cpg_out = attrs.out_c / attrs.groups;
    debug_assert!(oc1 <= attrs.out_c && oy1 <= oh && tx1 <= ow);
    debug_assert!(qw.len() >= attrs.out_c * cpg_in * attrs.kh * attrs.kw);
    if oc0 >= oc1 || oy0 >= oy1 || tx0 >= tx1 {
        return;
    }
    let kw_elems = attrs.kh * attrs.kw;
    let (stride, pad) = (attrs.stride, attrs.pad);
    let mut acc = vec![0i32; ow];
    for oc in oc0..oc1 {
        let g = oc / cpg_out;
        let w_base = oc * cpg_in * kw_elems;
        let b0 = if bias.is_empty() { 0.0 } else { bias[oc] };
        let dq = sx * sw[oc];
        for oy in oy0..oy1 {
            acc[tx0..tx1].fill(0);
            let iy0 = (oy * stride) as isize - pad as isize;
            for ic in 0..cpg_in {
                let c_in = g * cpg_in + ic;
                let wk = w_base + ic * kw_elems;
                for ky in 0..attrs.kh {
                    let iy = iy0 + ky as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let in_off = ((b * in_c + c_in) * h + iy as usize) * w;
                    let in_row = &qx[in_off..in_off + w];
                    for kx in 0..attrs.kw {
                        let wv = qw[wk + ky * attrs.kw + kx] as i32;
                        if wv == 0 {
                            continue;
                        }
                        let ix0 = kx as isize - pad as isize;
                        let ox_lo = if ix0 < 0 {
                            ((-ix0) as usize).div_ceil(stride)
                        } else {
                            0
                        }
                        .max(tx0);
                        if (ox_lo * stride) as isize + ix0 >= w as isize {
                            continue;
                        }
                        let ox_hi = (((w as isize - 1 - ix0) as usize) / stride + 1).min(tx1);
                        if ox_lo >= ox_hi {
                            continue;
                        }
                        let base = (ox_lo * stride) as isize + ix0;
                        let mut ix = base as usize;
                        for av in &mut acc[ox_lo..ox_hi] {
                            *av += wv * in_row[ix] as i32;
                            ix += stride;
                        }
                    }
                }
            }
            let out_off = ((b * attrs.out_c + oc) * oh + oy) * ow;
            let out_row = std::slice::from_raw_parts_mut(out.add(out_off), ow);
            for ox in tx0..tx1 {
                out_row[ox] = acc[ox] as f32 * dq + b0;
            }
        }
    }
}

/// Packed-panel i8 matmul over columns `[j0, j1)`:
/// `out[i, j] = acc_i32(i, j) · row_scale(i) · col_scale(j) + row_bias[i]
/// + col_bias[j]`, with `a` `[m, k]` and `bmat` `[k, n]` row-major i8.
/// `row_scale`/`col_scale` are per-row/column, or uniform when length 1;
/// the bias slices may be empty.
///
/// # Safety
/// `out` must point at a live `m*n` f32 buffer. Concurrent calls on the
/// same buffer must use disjoint column ranges (or disjoint row blocks
/// via offset `a`/`out` pointers).
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_panel_raw_q8(
    a: &[i8],
    m: usize,
    k: usize,
    bmat: &[i8],
    n: usize,
    j0: usize,
    j1: usize,
    row_scale: &[f32],
    col_scale: &[f32],
    row_bias: &[f32],
    col_bias: &[f32],
    out: *mut f32,
) {
    debug_assert!(a.len() >= m * k, "q8 lhs too small");
    debug_assert!(bmat.len() >= k * n, "q8 rhs too small");
    debug_assert!(j0 <= j1 && j1 <= n, "bad q8 column range");
    if m == 0 || j0 == j1 {
        return;
    }
    let mut packed = vec![0i8; k * NR];
    let mut jb = j0;
    while jb < j1 {
        let nw = NR.min(j1 - jb);
        for kk in 0..k {
            packed[kk * nw..kk * nw + nw].copy_from_slice(&bmat[kk * n + jb..kk * n + jb + nw]);
        }
        let mut i = 0;
        while i + MR <= m {
            let mut acc = [[0i32; NR]; MR];
            let a0 = &a[i * k..(i + 1) * k];
            let a1 = &a[(i + 1) * k..(i + 2) * k];
            let a2 = &a[(i + 2) * k..(i + 3) * k];
            let a3 = &a[(i + 3) * k..(i + 4) * k];
            for kk in 0..k {
                let pb = &packed[kk * nw..kk * nw + nw];
                let (v0, v1, v2, v3) =
                    (a0[kk] as i32, a1[kk] as i32, a2[kk] as i32, a3[kk] as i32);
                for (jj, &bv) in pb.iter().enumerate() {
                    let bv = bv as i32;
                    acc[0][jj] += v0 * bv;
                    acc[1][jj] += v1 * bv;
                    acc[2][jj] += v2 * bv;
                    acc[3][jj] += v3 * bv;
                }
            }
            for (r, row_acc) in acc.iter().enumerate() {
                store_row_q8(
                    row_acc,
                    nw,
                    out.add((i + r) * n + jb),
                    jb,
                    i + r,
                    row_scale,
                    col_scale,
                    row_bias,
                    col_bias,
                );
            }
            i += MR;
        }
        while i < m {
            let mut acc = [0i32; NR];
            let ar = &a[i * k..(i + 1) * k];
            for kk in 0..k {
                let pb = &packed[kk * nw..kk * nw + nw];
                let v = ar[kk] as i32;
                for (jj, &bv) in pb.iter().enumerate() {
                    acc[jj] += v * bv as i32;
                }
            }
            store_row_q8(
                &acc,
                nw,
                out.add(i * n + jb),
                jb,
                i,
                row_scale,
                col_scale,
                row_bias,
                col_bias,
            );
            i += 1;
        }
        jb += nw;
    }
}

/// Requantize one accumulated row segment to f32 with scales and biases.
///
/// # Safety
/// `dst` must point at `nw` writable f32 slots.
#[allow(clippy::too_many_arguments)]
#[inline]
unsafe fn store_row_q8(
    acc: &[i32; NR],
    nw: usize,
    dst: *mut f32,
    jb: usize,
    row: usize,
    row_scale: &[f32],
    col_scale: &[f32],
    row_bias: &[f32],
    col_bias: &[f32],
) {
    let rs = sc(row_scale, row);
    for (jj, &v) in acc.iter().enumerate().take(nw) {
        let mut y = v as f32 * rs * sc(col_scale, jb + jj);
        if !row_bias.is_empty() {
            y += row_bias[row];
        }
        if !col_bias.is_empty() {
            y += col_bias[jb + jj];
        }
        *dst.add(jj) = y;
    }
}

/// Quantized 1×1/s1 conv tile as a grouped packed i8 panel product:
/// weight rows `oc0..oc1` × pixel columns `[j0, j1)`, one panel product
/// per intersected convolution group (mirrors `ops::conv::
/// pointwise_tile_raw`).
///
/// # Safety
/// `out` must point at a live `out_c*hw` f32 buffer (batch 1); concurrent
/// calls must use disjoint `(oc, pixel)` regions.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn pointwise_tile_raw_q8(
    qx: &[i8],
    hw: usize,
    attrs: &ConvAttrs,
    qw: &[i8],
    sw: &[f32],
    bias: &[f32],
    sx: f32,
    oc0: usize,
    oc1: usize,
    j0: usize,
    j1: usize,
    out: *mut f32,
) {
    let cpg_in = attrs.in_c / attrs.groups;
    let cpg_out = attrs.out_c / attrs.groups;
    debug_assert!(oc0 <= oc1 && oc1 <= attrs.out_c);
    debug_assert!(j0 <= j1 && j1 <= hw);
    let sx_one = [sx];
    let mut r0 = oc0;
    while r0 < oc1 {
        let g = r0 / cpg_out;
        let r1 = ((g + 1) * cpg_out).min(oc1);
        let a = &qw[r0 * cpg_in..r1 * cpg_in];
        let xg = &qx[g * cpg_in * hw..(g + 1) * cpg_in * hw];
        let row_bias = if bias.is_empty() { &[][..] } else { &bias[r0..r1] };
        // SAFETY: rows r0..r1 write only columns [j0, j1) of the disjoint
        // slice [r0*hw, r1*hw).
        matmul_panel_raw_q8(
            a,
            r1 - r0,
            cpg_in,
            xg,
            hw,
            j0,
            j1,
            &sw[r0..r1],
            &sx_one,
            row_bias,
            &[],
            out.add(r0 * hw),
        );
        r0 = r1;
    }
}

/// Quantized counterpart of `ops::conv::conv2d_region_raw`: one output
/// region of a batch-1 quantized convolution, routed exactly as the
/// serial entry — 1×1/s1 through the packed i8 panel, everything else
/// through the generic q8 tile.
///
/// # Safety
/// As [`conv2d_tile_raw_q8`]; concurrent calls must target disjoint
/// regions.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn conv2d_region_raw_q8(
    qx: &[i8],
    in_c: usize,
    h: usize,
    w: usize,
    attrs: &ConvAttrs,
    qw: &QWeights,
    bias: &[f32],
    sx: f32,
    oc0: usize,
    oc1: usize,
    oy0: usize,
    oy1: usize,
    ox0: usize,
    ox1: usize,
    oh: usize,
    ow: usize,
    out: *mut f32,
) {
    if oc0 >= oc1 || oy0 >= oy1 || ox0 >= ox1 {
        return;
    }
    if is_pointwise_fast_path(attrs, 1) {
        let hw = h * w;
        if ox0 == 0 && ox1 == ow {
            pointwise_tile_raw_q8(
                qx, hw, attrs, &qw.q, &qw.scale, bias, sx, oc0, oc1, oy0 * ow, oy1 * ow, out,
            );
        } else {
            for oy in oy0..oy1 {
                pointwise_tile_raw_q8(
                    qx,
                    hw,
                    attrs,
                    &qw.q,
                    &qw.scale,
                    bias,
                    sx,
                    oc0,
                    oc1,
                    oy * ow + ox0,
                    oy * ow + ox1,
                    out,
                );
            }
        }
        return;
    }
    conv2d_tile_raw_q8(
        qx, in_c, h, w, attrs, &qw.q, &qw.scale, bias, sx, 0, oc0, oc1, oy0, oy1, ox0, ox1, oh,
        ow, out,
    );
}

/// Serial quantized convolution entry: quantized input `qx` (`[n, in_c,
/// h, w]` at scale `sx`), quantized weights, f32 bias — returns the
/// requantized f32 output. Routes like `ops::conv::conv2d`.
pub(crate) fn conv2d_q8(
    qx: &[i8],
    n: usize,
    in_c: usize,
    h: usize,
    w: usize,
    attrs: &ConvAttrs,
    qw: &QWeights,
    bias: &[f32],
    sx: f32,
) -> Tensor {
    let (oh, ow) = attrs.out_hw(h, w);
    let mut out = Tensor::zeros(TensorDesc::fm(n, attrs.out_c, oh, ow));
    if is_pointwise_fast_path(attrs, n) {
        // SAFETY: single-threaded call covering the whole [out_c, hw] range.
        unsafe {
            pointwise_tile_raw_q8(
                qx,
                oh * ow,
                attrs,
                &qw.q,
                &qw.scale,
                bias,
                sx,
                0,
                attrs.out_c,
                0,
                oh * ow,
                out.data.as_mut_ptr(),
            )
        };
        return out;
    }
    for b in 0..n {
        // SAFETY: single-threaded call covering the whole range of `b`.
        unsafe {
            conv2d_tile_raw_q8(
                qx,
                in_c,
                h,
                w,
                attrs,
                &qw.q,
                &qw.scale,
                bias,
                sx,
                b,
                0,
                attrs.out_c,
                0,
                oh,
                0,
                ow,
                oh,
                ow,
                out.data.as_mut_ptr(),
            )
        };
    }
    out
}

/// Serial quantized FC: `[rows, k] × [k, n]` with per-column weight
/// scales and f32 bias.
pub(crate) fn fc_q8(
    qa: &[i8],
    rows: usize,
    k: usize,
    n: usize,
    qw: &QWeights,
    bias: &[f32],
    sx: f32,
) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * n];
    let sx_one = [sx];
    // SAFETY: `out` is exactly rows*n and the single call covers all columns.
    unsafe {
        matmul_panel_raw_q8(
            qa,
            rows,
            k,
            &qw.q,
            n,
            0,
            n,
            &sx_one,
            &qw.scale,
            &[],
            bias,
            out.as_mut_ptr(),
        )
    };
    out
}

/// Serial quantized activation×activation matmul (`[m, k] × [k, n]`),
/// uniform scales.
pub(crate) fn matmul_q8(
    qa: &[i8],
    m: usize,
    k: usize,
    qb: &[i8],
    n: usize,
    sa: f32,
    sb: f32,
) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    let (sa_one, sb_one) = ([sa], [sb]);
    // SAFETY: `out` is exactly m*n and the single call covers all columns.
    unsafe {
        matmul_panel_raw_q8(qa, m, k, qb, n, 0, n, &sa_one, &sb_one, &[], &[], out.as_mut_ptr())
    };
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_slice, scale_for};
    use crate::util::rng::Rng;

    /// i64 reference for the q8 conv (no tiling, no panel packing).
    #[allow(clippy::too_many_arguments)]
    fn conv_ref(
        qx: &[i8],
        in_c: usize,
        h: usize,
        w: usize,
        a: &ConvAttrs,
        qw: &[i8],
        sw: &[f32],
        bias: &[f32],
        sx: f32,
    ) -> Vec<f32> {
        let (oh, ow) = a.out_hw(h, w);
        let cpg_in = a.in_c / a.groups;
        let cpg_out = a.out_c / a.groups;
        let mut out = vec![0.0f32; a.out_c * oh * ow];
        for oc in 0..a.out_c {
            let g = oc / cpg_out;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc: i64 = 0;
                    for ic in 0..cpg_in {
                        for ky in 0..a.kh {
                            for kx in 0..a.kw {
                                let iy = (oy * a.stride + ky) as isize - a.pad as isize;
                                let ix = (ox * a.stride + kx) as isize - a.pad as isize;
                                if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let xv = qx
                                    [((g * cpg_in + ic) * h + iy as usize) * w + ix as usize]
                                    as i64;
                                let wv = qw[(oc * cpg_in + ic) * a.kh * a.kw + ky * a.kw + kx]
                                    as i64;
                                acc += xv * wv;
                            }
                        }
                    }
                    let b0 = if bias.is_empty() { 0.0 } else { bias[oc] };
                    out[(oc * oh + oy) * ow + ox] = acc as i32 as f32 * (sx * sw[oc]) + b0;
                }
            }
        }
        out
    }

    #[test]
    fn q8_conv_matches_integer_reference() {
        let mut rng = Rng::new(50);
        for a in [
            ConvAttrs::std(3, 5, 3, 1, 1),
            ConvAttrs::std(4, 6, 3, 2, 1),
            ConvAttrs::depthwise(4, 3, 1, 1),
            ConvAttrs::std(4, 4, 1, 1, 0),
        ] {
            let (h, w) = (7usize, 9usize);
            let x = rng.vec_uniform(a.in_c * h * w);
            let sx = scale_for(1.0);
            let qx = quantize_slice(&x, sx);
            let wts = rng.vec_uniform(a.weight_count() as usize);
            let qw = QWeights::per_row(&wts, a.out_c, a.in_c / a.groups * a.kh * a.kw);
            let bias = rng.vec_uniform(a.out_c);
            let got = conv2d_q8(&qx, 1, a.in_c, h, w, &a, &qw, &bias, sx);
            let want = conv_ref(&qx, a.in_c, h, w, &a, &qw.q, &qw.scale, &bias, sx);
            assert_eq!(got.data, want, "attrs {a:?}");
        }
    }

    #[test]
    fn q8_region_tiles_match_full_bitwise() {
        let mut rng = Rng::new(51);
        for a in [
            ConvAttrs::std(4, 6, 3, 1, 1),
            ConvAttrs::std(6, 6, 1, 1, 0), // pointwise panel path
            ConvAttrs::depthwise(6, 3, 1, 1),
        ] {
            let (h, w) = (8usize, 8usize);
            let x = rng.vec_uniform(a.in_c * h * w);
            let sx = scale_for(1.0);
            let qx = quantize_slice(&x, sx);
            let wts = rng.vec_uniform(a.weight_count() as usize);
            let qw = QWeights::per_row(&wts, a.out_c, a.in_c / a.groups * a.kh * a.kw);
            let bias = rng.vec_uniform(a.out_c);
            let full = conv2d_q8(&qx, 1, a.in_c, h, w, &a, &qw, &bias, sx);
            let (oh, ow) = a.out_hw(h, w);
            for splits in [
                vec![(0, 2, 0, oh, 0, ow), (2, a.out_c, 0, oh, 0, ow)],
                vec![(0, a.out_c, 0, 3, 0, ow), (0, a.out_c, 3, oh, 0, ow)],
                vec![(0, a.out_c, 0, oh, 0, 5), (0, a.out_c, 0, oh, 5, ow)],
            ] {
                let mut got = vec![0.0f32; a.out_c * oh * ow];
                for (c0, c1, y0, y1, x0, x1) in splits {
                    unsafe {
                        conv2d_region_raw_q8(
                            &qx, a.in_c, h, w, &a, &qw, &bias, sx, c0, c1, y0, y1, x0, x1, oh,
                            ow, got.as_mut_ptr(),
                        )
                    };
                }
                assert_eq!(got, full.data, "attrs {a:?}");
            }
        }
    }

    #[test]
    fn q8_matmul_matches_integer_reference_and_column_splits() {
        let mut rng = Rng::new(52);
        let (m, k, n) = (7usize, 33usize, 19usize);
        let a: Vec<i8> = quantize_slice(&rng.vec_uniform(m * k), scale_for(1.0));
        let b: Vec<i8> = quantize_slice(&rng.vec_uniform(k * n), scale_for(1.0));
        let (sa, sb) = (0.013f32, 0.02f32);
        let full = matmul_q8(&a, m, k, &b, n, sa, sb);
        // Integer reference.
        for i in 0..m {
            for j in 0..n {
                let mut acc: i64 = 0;
                for kk in 0..k {
                    acc += a[i * k + kk] as i64 * b[kk * n + j] as i64;
                }
                assert_eq!(full[i * n + j], acc as i32 as f32 * sa * sb);
            }
        }
        // Column splits are bit-identical.
        let mut split = vec![0.0f32; m * n];
        let (sa_one, sb_one) = ([sa], [sb]);
        for (j0, j1) in [(0usize, 5usize), (5, 12), (12, 19)] {
            unsafe {
                matmul_panel_raw_q8(
                    &a, m, k, &b, n, j0, j1, &sa_one, &sb_one, &[], &[], split.as_mut_ptr(),
                )
            };
        }
        assert_eq!(full, split);
    }

    #[test]
    fn q8_fc_applies_per_column_scales_and_bias() {
        let mut rng = Rng::new(53);
        let (rows, k, n) = (3usize, 10usize, 6usize);
        let x = rng.vec_uniform(rows * k);
        let sx = scale_for(1.0);
        let qa = quantize_slice(&x, sx);
        let w = rng.vec_uniform(k * n);
        let qw = QWeights::per_col(&w, k, n);
        let bias = rng.vec_uniform(n);
        let got = fc_q8(&qa, rows, k, n, &qw, &bias, sx);
        for i in 0..rows {
            for j in 0..n {
                let mut acc: i64 = 0;
                for kk in 0..k {
                    acc += qa[i * k + kk] as i64 * qw.q[kk * n + j] as i64;
                }
                let want = acc as i32 as f32 * sx * qw.scale[j] + bias[j];
                assert_eq!(got[i * n + j], want, "({i},{j})");
            }
        }
    }

    #[test]
    fn saturated_inputs_cannot_overflow_i32() {
        // Adversarial case: every operand saturated at ±127 over the
        // largest reduction in the zoo (2048·3·3) stays far below i32::MAX,
        // and the kernel reproduces the exact integer sum.
        let k = 2048 * 9;
        let qa = vec![127i8; k];
        let qb = vec![-127i8; k]; // [k, 1]
        let got = matmul_q8(&qa, 1, k, &qb, 1, 1.0, 1.0);
        let want = -(127i64 * 127 * k as i64);
        assert!(want.abs() < i32::MAX as i64);
        assert_eq!(got[0], want as i32 as f32);
    }
}
