//! INT8 tile kernels with i32 accumulation, mirroring the f32 kernels in
//! `ops::conv` / `ops::matmul` tile-for-tile so the parallel executor's
//! (oc, oy) chunking, the pointwise fast path and the d-Xenos region
//! shards route identically at both precisions.
//!
//! Every kernel is generic over its **epilogue** — how a finished i32
//! accumulator segment becomes output elements:
//!
//! * `FixedQ8` — the fused requantize epilogue: per-output-channel
//!   fixed-point multiplier+shift (+ bias, + optional fused ReLU as a
//!   zero clamp) straight to i8 codes. This is the integer-resident hot
//!   path: `IntDot → IntDot` edges never materialize f32.
//! * `DeqF32` — dequantize to f32 with per-row/column scales and
//!   biases; used where a float stage follows before requantization
//!   (the linked CBRA/CBRM operators pool in f32) and at dequantize
//!   boundaries.
//!
//! Correctness note that makes quantized execution *easier* to
//! distribute than f32: the per-element reduction is an exact integer sum
//! (`i8 × i8 → i32`; worst case `127·127·k` stays far below `i32::MAX`
//! for every shape in the zoo), and both epilogues are pure per-element
//! functions of the accumulator, so **any** tiling or chunk order yields
//! bit-identical output. Parallel and sharded runs therefore match the
//! serial kernel without the careful shared-loop-order argument the f32
//! path needs.

use super::fix_requant1;
use crate::graph::ConvAttrs;
use crate::ops::conv::is_pointwise_fast_path;

/// Register-tile width of the packed i8 panel (matches the f32 kernel).
const NR: usize = 8;
/// Register-tile height.
const MR: usize = 4;

/// Scale lookup that treats a length-1 slice as uniform.
#[inline]
fn sc(scales: &[f32], i: usize) -> f32 {
    if scales.len() == 1 {
        scales[0]
    } else {
        scales[i]
    }
}

/// How one finished i32 accumulator segment becomes output elements.
/// `store` writes `acc.len()` elements for output row `r` (output channel
/// for convs, lhs row for matmuls), columns `c0..c0+acc.len()`, starting
/// at `dst`.
pub(crate) trait Epilogue: Sync {
    type Out: Copy + Default;
    /// # Safety
    /// `dst` must point at `acc.len()` writable `Out` slots.
    unsafe fn store(&self, r: usize, c0: usize, acc: &[i32], dst: *mut Self::Out);
}

/// Dequantizing f32 epilogue: `out = acc · row_scale(r) · col_scale(c) +
/// row_bias[r] + col_bias[c]`. Scales are per-row/column or uniform when
/// length 1; the bias slices may be empty.
pub(crate) struct DeqF32<'a> {
    pub row_scale: &'a [f32],
    pub col_scale: &'a [f32],
    pub row_bias: &'a [f32],
    pub col_bias: &'a [f32],
}

/// The uniform unit column scale for epilogues whose full dequant factor
/// lives on the row axis (convolutions with folded input grids).
pub(crate) const UNIT: [f32; 1] = [1.0];

impl Epilogue for DeqF32<'_> {
    type Out = f32;

    #[inline]
    unsafe fn store(&self, r: usize, c0: usize, acc: &[i32], dst: *mut f32) {
        let rs = sc(self.row_scale, r);
        for (i, &v) in acc.iter().enumerate() {
            let mut y = v as f32 * rs * sc(self.col_scale, c0 + i);
            if !self.row_bias.is_empty() {
                y += self.row_bias[r];
            }
            if !self.col_bias.is_empty() {
                y += self.col_bias[c0 + i];
            }
            *dst.add(i) = y;
        }
    }
}

/// The fused fixed-point requantize epilogue: `code = clamp(round(acc ·
/// mult·2^-shift + bias·2^-shift), lo, 127)`, per output channel
/// (`by_col = false`, conv rows) or per output column (`by_col = true`,
/// FC columns). Length-1 parameter slices are uniform. `lo = 0` fuses a
/// ReLU into the clamp.
pub(crate) struct FixedQ8<'a> {
    pub mult: &'a [i32],
    pub shift: &'a [u8],
    pub bias: &'a [i64],
    pub lo: i8,
    pub by_col: bool,
}

impl Epilogue for FixedQ8<'_> {
    type Out = i8;

    #[inline]
    unsafe fn store(&self, r: usize, c0: usize, acc: &[i32], dst: *mut i8) {
        if self.by_col {
            for (i, &v) in acc.iter().enumerate() {
                let k = if self.mult.len() == 1 { 0 } else { c0 + i };
                *dst.add(i) =
                    fix_requant1(v, self.mult[k], self.shift[k], self.bias[k], self.lo);
            }
        } else {
            let k = if self.mult.len() == 1 { 0 } else { r };
            let (m, s, b) = (self.mult[k], self.shift[k], self.bias[k]);
            for (i, &v) in acc.iter().enumerate() {
                *dst.add(i) = fix_requant1(v, m, s, b, self.lo);
            }
        }
    }
}

/// Raw-accumulator epilogue: stores the exact i32 accumulators untouched.
/// The shard-resident partial-sum path runs the conv kernels with this
/// epilogue so per-rank input-channel partials can be reduce-scattered
/// exactly (`i32` addition is associative) before the owning rank applies
/// the real [`FixedQ8`] epilogue to the complete sum.
pub(crate) struct RawAcc;

impl Epilogue for RawAcc {
    type Out = i32;

    #[inline]
    unsafe fn store(&self, _r: usize, _c0: usize, acc: &[i32], dst: *mut i32) {
        std::ptr::copy_nonoverlapping(acc.as_ptr(), dst, acc.len());
    }
}

/// Row-offset adapter: presents an inner epilogue with `r0` added to
/// every row index. The pointwise conv routes weight-row blocks through
/// the packed panel kernel with block-local row numbers; this keeps the
/// epilogue's per-output-channel indexing global.
struct OffsetRows<'a, E: Epilogue> {
    ep: &'a E,
    r0: usize,
}

impl<E: Epilogue> Epilogue for OffsetRows<'_, E> {
    type Out = E::Out;

    #[inline]
    unsafe fn store(&self, r: usize, c0: usize, acc: &[i32], dst: *mut E::Out) {
        self.ep.store(self.r0 + r, c0, acc, dst);
    }
}

/// Generic quantized conv tile: output channels `oc0..oc1`, rows
/// `oy0..oy1`, columns `tx0..tx1` of batch `b`, written through the
/// epilogue into the full `[n, out_c, oh, ow]` buffer behind `out`.
///
/// `qx` is the i8 input `[n, in_c, h, w]`; `qw` the i8 weights in f32
/// layout. The epilogue's row index is the output channel.
///
/// # Safety
/// `out` must point at a live `n*out_c*oh*ow` buffer. Concurrent calls
/// on the same buffer must target disjoint `(oc, oy, ox)` tiles.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn conv2d_tile_raw_q8<E: Epilogue>(
    qx: &[i8],
    in_c: usize,
    h: usize,
    w: usize,
    attrs: &ConvAttrs,
    qw: &[i8],
    ep: &E,
    b: usize,
    oc0: usize,
    oc1: usize,
    oy0: usize,
    oy1: usize,
    tx0: usize,
    tx1: usize,
    oh: usize,
    ow: usize,
    out: *mut E::Out,
) {
    debug_assert_eq!(in_c, attrs.in_c, "q8 conv input channels");
    let cpg_in = attrs.in_c / attrs.groups;
    let cpg_out = attrs.out_c / attrs.groups;
    debug_assert!(oc1 <= attrs.out_c && oy1 <= oh && tx1 <= ow);
    debug_assert!(qw.len() >= attrs.out_c * cpg_in * attrs.kh * attrs.kw);
    if oc0 >= oc1 || oy0 >= oy1 || tx0 >= tx1 {
        return;
    }
    let kw_elems = attrs.kh * attrs.kw;
    let (stride, pad) = (attrs.stride, attrs.pad);
    let mut acc = vec![0i32; ow];
    for oc in oc0..oc1 {
        let g = oc / cpg_out;
        let w_base = oc * cpg_in * kw_elems;
        for oy in oy0..oy1 {
            acc[tx0..tx1].fill(0);
            let iy0 = (oy * stride) as isize - pad as isize;
            for ic in 0..cpg_in {
                let c_in = g * cpg_in + ic;
                let wk = w_base + ic * kw_elems;
                for ky in 0..attrs.kh {
                    let iy = iy0 + ky as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let in_off = ((b * in_c + c_in) * h + iy as usize) * w;
                    let in_row = &qx[in_off..in_off + w];
                    for kx in 0..attrs.kw {
                        let wv = qw[wk + ky * attrs.kw + kx] as i32;
                        if wv == 0 {
                            continue;
                        }
                        let ix0 = kx as isize - pad as isize;
                        let ox_lo = if ix0 < 0 {
                            ((-ix0) as usize).div_ceil(stride)
                        } else {
                            0
                        }
                        .max(tx0);
                        if (ox_lo * stride) as isize + ix0 >= w as isize {
                            continue;
                        }
                        let ox_hi = (((w as isize - 1 - ix0) as usize) / stride + 1).min(tx1);
                        if ox_lo >= ox_hi {
                            continue;
                        }
                        let base = (ox_lo * stride) as isize + ix0;
                        let mut ix = base as usize;
                        for av in &mut acc[ox_lo..ox_hi] {
                            *av += wv * in_row[ix] as i32;
                            ix += stride;
                        }
                    }
                }
            }
            let out_off = ((b * attrs.out_c + oc) * oh + oy) * ow;
            ep.store(oc, tx0, &acc[tx0..tx1], out.add(out_off + tx0));
        }
    }
}

/// Packed-panel i8 matmul over columns `[j0, j1)` of `a [m, k] × bmat
/// [k, n]` (both row-major i8), accumulators finished through the
/// epilogue (row index = lhs row, column index = rhs column).
///
/// # Safety
/// `out` must point at a live `m*n` buffer. Concurrent calls on the
/// same buffer must use disjoint column ranges (or disjoint row blocks
/// via offset `a`/`out` pointers).
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_panel_raw_q8<E: Epilogue>(
    a: &[i8],
    m: usize,
    k: usize,
    bmat: &[i8],
    n: usize,
    j0: usize,
    j1: usize,
    ep: &E,
    out: *mut E::Out,
) {
    matmul_panel_raw_q8_batch(&[a], m, k, bmat, n, j0, j1, ep, &[out]);
}

/// Batched packed-panel i8 matmul: `N` independent `[m, k]` left-hand
/// operands against one `bmat`, each writing its own `outs[s]` buffer.
/// Each `NR`-column panel of `bmat` is packed **once** and swept across
/// the whole batch (a per-sample loop re-packs it `N` times); the exact
/// integer accumulation makes batched output trivially bit-identical to
/// `N` solo [`matmul_panel_raw_q8`] calls.
///
/// # Safety
/// Each `outs[s]` must point at a live `m*n` buffer; buffers must be
/// pairwise disjoint. Concurrency rules per buffer as
/// [`matmul_panel_raw_q8`].
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn matmul_panel_raw_q8_batch<E: Epilogue>(
    a_batch: &[&[i8]],
    m: usize,
    k: usize,
    bmat: &[i8],
    n: usize,
    j0: usize,
    j1: usize,
    ep: &E,
    outs: &[*mut E::Out],
) {
    debug_assert_eq!(a_batch.len(), outs.len(), "q8 batch size mismatch");
    debug_assert!(a_batch.iter().all(|a| a.len() >= m * k), "q8 lhs too small");
    debug_assert!(bmat.len() >= k * n, "q8 rhs too small");
    debug_assert!(j0 <= j1 && j1 <= n, "bad q8 column range");
    if m == 0 || j0 == j1 || a_batch.is_empty() {
        return;
    }
    let mut packed = vec![0i8; k * NR];
    let mut jb = j0;
    while jb < j1 {
        let nw = NR.min(j1 - jb);
        // Pack B[:, jb..jb+nw] once for the whole batch.
        for kk in 0..k {
            packed[kk * nw..kk * nw + nw].copy_from_slice(&bmat[kk * n + jb..kk * n + jb + nw]);
        }
        for (a, &out) in a_batch.iter().zip(outs) {
            panel_rows_q8(a, m, k, n, &packed, jb, nw, ep, out);
        }
        jb += nw;
    }
}

/// One sample's row sweep against a pre-packed `nw`-column i8 panel —
/// the register-tiled core shared by the single and batched q8 entries.
///
/// # Safety
/// As [`matmul_panel_raw_q8`] for the `[jb, jb+nw)` column range of `out`.
#[allow(clippy::too_many_arguments)]
unsafe fn panel_rows_q8<E: Epilogue>(
    a: &[i8],
    m: usize,
    k: usize,
    n: usize,
    packed: &[i8],
    jb: usize,
    nw: usize,
    ep: &E,
    out: *mut E::Out,
) {
    let mut i = 0;
    while i + MR <= m {
        let mut acc = [[0i32; NR]; MR];
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let a2 = &a[(i + 2) * k..(i + 3) * k];
        let a3 = &a[(i + 3) * k..(i + 4) * k];
        for kk in 0..k {
            let pb = &packed[kk * nw..kk * nw + nw];
            let (v0, v1, v2, v3) = (a0[kk] as i32, a1[kk] as i32, a2[kk] as i32, a3[kk] as i32);
            for (jj, &bv) in pb.iter().enumerate() {
                let bv = bv as i32;
                acc[0][jj] += v0 * bv;
                acc[1][jj] += v1 * bv;
                acc[2][jj] += v2 * bv;
                acc[3][jj] += v3 * bv;
            }
        }
        for (r, row_acc) in acc.iter().enumerate() {
            ep.store(i + r, jb, &row_acc[..nw], out.add((i + r) * n + jb));
        }
        i += MR;
    }
    while i < m {
        let mut acc = [0i32; NR];
        let ar = &a[i * k..(i + 1) * k];
        for kk in 0..k {
            let pb = &packed[kk * nw..kk * nw + nw];
            let v = ar[kk] as i32;
            for (jj, &bv) in pb.iter().enumerate() {
                acc[jj] += v * bv as i32;
            }
        }
        ep.store(i, jb, &acc[..nw], out.add(i * n + jb));
        i += 1;
    }
}

/// Quantized 1×1/s1 conv tile as a grouped packed i8 panel product:
/// weight rows `oc0..oc1` × pixel columns `[j0, j1)`, one panel product
/// per intersected convolution group (mirrors `ops::conv::
/// pointwise_tile_raw`). The epilogue sees **global** output-channel row
/// indices.
///
/// # Safety
/// `out` must point at a live `out_c*hw` buffer (batch 1); concurrent
/// calls must use disjoint `(oc, pixel)` regions.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn pointwise_tile_raw_q8<E: Epilogue>(
    qx: &[i8],
    hw: usize,
    attrs: &ConvAttrs,
    qw: &[i8],
    ep: &E,
    oc0: usize,
    oc1: usize,
    j0: usize,
    j1: usize,
    out: *mut E::Out,
) {
    let cpg_in = attrs.in_c / attrs.groups;
    let cpg_out = attrs.out_c / attrs.groups;
    debug_assert!(oc0 <= oc1 && oc1 <= attrs.out_c);
    debug_assert!(j0 <= j1 && j1 <= hw);
    let mut r0 = oc0;
    while r0 < oc1 {
        let g = r0 / cpg_out;
        let r1 = ((g + 1) * cpg_out).min(oc1);
        let a = &qw[r0 * cpg_in..r1 * cpg_in];
        let xg = &qx[g * cpg_in * hw..(g + 1) * cpg_in * hw];
        let off = OffsetRows { ep, r0 };
        // SAFETY: rows r0..r1 write only columns [j0, j1) of the disjoint
        // slice [r0*hw, r1*hw).
        matmul_panel_raw_q8(a, r1 - r0, cpg_in, xg, hw, j0, j1, &off, out.add(r0 * hw));
        r0 = r1;
    }
}

/// Quantized counterpart of `ops::conv::conv2d_region_raw`: one output
/// region of a batch-1 quantized convolution, routed exactly as the
/// serial entry — 1×1/s1 through the packed i8 panel, everything else
/// through the generic q8 tile.
///
/// # Safety
/// As [`conv2d_tile_raw_q8`]; concurrent calls must target disjoint
/// regions.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn conv2d_region_raw_q8<E: Epilogue>(
    qx: &[i8],
    in_c: usize,
    h: usize,
    w: usize,
    attrs: &ConvAttrs,
    qw: &[i8],
    ep: &E,
    oc0: usize,
    oc1: usize,
    oy0: usize,
    oy1: usize,
    ox0: usize,
    ox1: usize,
    oh: usize,
    ow: usize,
    out: *mut E::Out,
) {
    if oc0 >= oc1 || oy0 >= oy1 || ox0 >= ox1 {
        return;
    }
    if is_pointwise_fast_path(attrs, 1) {
        let hw = h * w;
        if ox0 == 0 && ox1 == ow {
            pointwise_tile_raw_q8(qx, hw, attrs, qw, ep, oc0, oc1, oy0 * ow, oy1 * ow, out);
        } else {
            for oy in oy0..oy1 {
                pointwise_tile_raw_q8(
                    qx,
                    hw,
                    attrs,
                    qw,
                    ep,
                    oc0,
                    oc1,
                    oy * ow + ox0,
                    oy * ow + ox1,
                    out,
                );
            }
        }
        return;
    }
    conv2d_tile_raw_q8(
        qx, in_c, h, w, attrs, qw, ep, 0, oc0, oc1, oy0, oy1, ox0, ox1, oh, ow, out,
    );
}

/// Serial quantized convolution entry: i8 input `[n, in_c, h, w]`, i8
/// weights, output elements produced by the epilogue (i8 codes for
/// [`FixedQ8`], f32 for [`DeqF32`]). Routes like `ops::conv::conv2d`.
pub(crate) fn conv2d_q8<E: Epilogue>(
    qx: &[i8],
    n: usize,
    in_c: usize,
    h: usize,
    w: usize,
    attrs: &ConvAttrs,
    qw: &[i8],
    ep: &E,
) -> Vec<E::Out> {
    let (oh, ow) = attrs.out_hw(h, w);
    let mut out = vec![E::Out::default(); n * attrs.out_c * oh * ow];
    if is_pointwise_fast_path(attrs, n) {
        // SAFETY: single-threaded call covering the whole [out_c, hw] range.
        unsafe {
            pointwise_tile_raw_q8(
                qx,
                oh * ow,
                attrs,
                qw,
                ep,
                0,
                attrs.out_c,
                0,
                oh * ow,
                out.as_mut_ptr(),
            )
        };
        return out;
    }
    for b in 0..n {
        // SAFETY: single-threaded call covering the whole range of `b`.
        unsafe {
            conv2d_tile_raw_q8(
                qx,
                in_c,
                h,
                w,
                attrs,
                qw,
                ep,
                b,
                0,
                attrs.out_c,
                0,
                oh,
                0,
                ow,
                oh,
                ow,
                out.as_mut_ptr(),
            )
        };
    }
    out
}

/// Serial quantized FC: `[rows, k] × [k, n]` through the epilogue
/// (column index = output feature).
pub(crate) fn fc_q8<E: Epilogue>(
    qa: &[i8],
    rows: usize,
    k: usize,
    n: usize,
    qw: &[i8],
    ep: &E,
) -> Vec<E::Out> {
    let mut out = vec![E::Out::default(); rows * n];
    // SAFETY: `out` is exactly rows*n and the single call covers all columns.
    unsafe { matmul_panel_raw_q8(qa, rows, k, qw, n, 0, n, ep, out.as_mut_ptr()) };
    out
}

/// Batched quantized FC: `N` samples' `[rows, k]` activations against one
/// `[k, n]` weight matrix, packing each weight panel once for the whole
/// batch. Bit-identical to per-sample [`fc_q8`] calls.
pub(crate) fn fc_q8_batch<E: Epilogue>(
    qa_batch: &[&[i8]],
    rows: usize,
    k: usize,
    n: usize,
    qw: &[i8],
    ep: &E,
) -> Vec<Vec<E::Out>> {
    let mut outs: Vec<Vec<E::Out>> =
        (0..qa_batch.len()).map(|_| vec![E::Out::default(); rows * n]).collect();
    let out_ptrs: Vec<*mut E::Out> = outs.iter_mut().map(|o| o.as_mut_ptr()).collect();
    // SAFETY: each out buffer is exactly rows*n and pairwise disjoint; the
    // single call covers all columns of each.
    unsafe { matmul_panel_raw_q8_batch(qa_batch, rows, k, qw, n, 0, n, ep, &out_ptrs) };
    outs
}

/// Serial quantized activation×activation matmul (`[m, k] × [k, n]`).
pub(crate) fn matmul_q8<E: Epilogue>(
    qa: &[i8],
    m: usize,
    k: usize,
    qb: &[i8],
    n: usize,
    ep: &E,
) -> Vec<E::Out> {
    let mut out = vec![E::Out::default(); m * n];
    // SAFETY: `out` is exactly m*n and the single call covers all columns.
    unsafe { matmul_panel_raw_q8(qa, m, k, qb, n, 0, n, ep, out.as_mut_ptr()) };
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fix_bias, fix_multiplier, quantize_slice, scale_for, QWeights};
    use crate::util::rng::Rng;

    /// i64 reference for the q8 conv accumulator, dequantized like the
    /// f32 epilogue (no tiling, no panel packing).
    #[allow(clippy::too_many_arguments)]
    fn conv_ref(
        qx: &[i8],
        h: usize,
        w: usize,
        a: &ConvAttrs,
        qw: &[i8],
        dq: &[f32],
        bias: &[f32],
    ) -> Vec<f32> {
        let acc = conv_acc_ref(qx, h, w, a, qw);
        let (oh, ow) = a.out_hw(h, w);
        let mut out = vec![0.0f32; a.out_c * oh * ow];
        for oc in 0..a.out_c {
            let b0 = if bias.is_empty() { 0.0 } else { bias[oc] };
            for i in 0..oh * ow {
                out[oc * oh * ow + i] = acc[oc * oh * ow + i] as f32 * dq[oc] * 1.0 + b0;
            }
        }
        out
    }

    /// Exact integer accumulators of a batch-1 q8 conv.
    fn conv_acc_ref(qx: &[i8], h: usize, w: usize, a: &ConvAttrs, qw: &[i8]) -> Vec<i32> {
        let (oh, ow) = a.out_hw(h, w);
        let cpg_in = a.in_c / a.groups;
        let cpg_out = a.out_c / a.groups;
        let mut out = vec![0i32; a.out_c * oh * ow];
        for oc in 0..a.out_c {
            let g = oc / cpg_out;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc: i64 = 0;
                    for ic in 0..cpg_in {
                        for ky in 0..a.kh {
                            for kx in 0..a.kw {
                                let iy = (oy * a.stride + ky) as isize - a.pad as isize;
                                let ix = (ox * a.stride + kx) as isize - a.pad as isize;
                                if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let xv = qx
                                    [((g * cpg_in + ic) * h + iy as usize) * w + ix as usize]
                                    as i64;
                                let wv = qw[(oc * cpg_in + ic) * a.kh * a.kw + ky * a.kw + kx]
                                    as i64;
                                acc += xv * wv;
                            }
                        }
                    }
                    out[(oc * oh + oy) * ow + ox] = acc as i32;
                }
            }
        }
        out
    }

    fn dq_of(qw: &QWeights, sx: f32) -> Vec<f32> {
        qw.scale.iter().map(|&s| sx * s).collect()
    }

    #[test]
    fn q8_conv_matches_integer_reference() {
        let mut rng = Rng::new(50);
        for a in [
            ConvAttrs::std(3, 5, 3, 1, 1),
            ConvAttrs::std(4, 6, 3, 2, 1),
            ConvAttrs::depthwise(4, 3, 1, 1),
            ConvAttrs::std(4, 4, 1, 1, 0),
        ] {
            let (h, w) = (7usize, 9usize);
            let x = rng.vec_uniform(a.in_c * h * w);
            let sx = scale_for(1.0);
            let qx = quantize_slice(&x, sx);
            let wts = rng.vec_uniform(a.weight_count() as usize);
            let qw = QWeights::per_row(&wts, a.out_c, a.in_c / a.groups * a.kh * a.kw);
            let bias = rng.vec_uniform(a.out_c);
            let dq = dq_of(&qw, sx);
            let ep = DeqF32 { row_scale: &dq, col_scale: &UNIT, row_bias: &bias, col_bias: &[] };
            let got = conv2d_q8(&qx, 1, a.in_c, h, w, &a, &qw.q, &ep);
            let want = conv_ref(&qx, h, w, &a, &qw.q, &dq, &bias);
            assert_eq!(got, want, "attrs {a:?}");
        }
    }

    #[test]
    fn q8_region_tiles_match_full_bitwise() {
        let mut rng = Rng::new(51);
        for a in [
            ConvAttrs::std(4, 6, 3, 1, 1),
            ConvAttrs::std(6, 6, 1, 1, 0), // pointwise panel path
            ConvAttrs::depthwise(6, 3, 1, 1),
        ] {
            let (h, w) = (8usize, 8usize);
            let x = rng.vec_uniform(a.in_c * h * w);
            let sx = scale_for(1.0);
            let qx = quantize_slice(&x, sx);
            let wts = rng.vec_uniform(a.weight_count() as usize);
            let qw = QWeights::per_row(&wts, a.out_c, a.in_c / a.groups * a.kh * a.kw);
            let bias = rng.vec_uniform(a.out_c);
            let dq = dq_of(&qw, sx);
            let ep = DeqF32 { row_scale: &dq, col_scale: &UNIT, row_bias: &bias, col_bias: &[] };
            let full = conv2d_q8(&qx, 1, a.in_c, h, w, &a, &qw.q, &ep);
            let (oh, ow) = a.out_hw(h, w);
            for splits in [
                vec![(0, 2, 0, oh, 0, ow), (2, a.out_c, 0, oh, 0, ow)],
                vec![(0, a.out_c, 0, 3, 0, ow), (0, a.out_c, 3, oh, 0, ow)],
                vec![(0, a.out_c, 0, oh, 0, 5), (0, a.out_c, 0, oh, 5, ow)],
            ] {
                let mut got = vec![0.0f32; a.out_c * oh * ow];
                for (c0, c1, y0, y1, x0, x1) in splits {
                    unsafe {
                        conv2d_region_raw_q8(
                            &qx, a.in_c, h, w, &a, &qw.q, &ep, c0, c1, y0, y1, x0, x1, oh, ow,
                            got.as_mut_ptr(),
                        )
                    };
                }
                assert_eq!(got, full, "attrs {a:?}");
            }
        }
    }

    #[test]
    fn fixed_epilogue_matches_scalar_reference_and_splits() {
        // The fused i8 epilogue reproduces fix_requant1 per element, for
        // every conv route (tile, pointwise panel, depthwise), and any
        // region split is bit-identical — the property that makes the
        // integer-resident path shardable.
        let mut rng = Rng::new(54);
        for a in [
            ConvAttrs::std(4, 6, 3, 1, 1),
            ConvAttrs::std(6, 8, 1, 1, 0),
            ConvAttrs::depthwise(6, 3, 1, 1),
        ] {
            let (h, w) = (8usize, 8usize);
            let x = rng.vec_uniform(a.in_c * h * w);
            let sx = scale_for(1.0);
            let qx = quantize_slice(&x, sx);
            let wts = rng.vec_uniform(a.weight_count() as usize);
            let qw = QWeights::per_row(&wts, a.out_c, a.in_c / a.groups * a.kh * a.kw);
            let bias = rng.vec_uniform(a.out_c);
            let s_out = scale_for(2.0);
            // Per-channel fixed-point plan: code = round(acc·sx·sw/s_out +
            // bias/s_out), fused ReLU on odd channels.
            let mut mult = Vec::new();
            let mut shift = Vec::new();
            let mut bfx = Vec::new();
            for oc in 0..a.out_c {
                let (m, s) = fix_multiplier(sx * qw.scale[oc] / s_out);
                mult.push(m);
                shift.push(s);
                bfx.push(fix_bias(bias[oc] / s_out, s));
            }
            for lo in [-127i8, 0] {
                let ep =
                    FixedQ8 { mult: &mult, shift: &shift, bias: &bfx, lo, by_col: false };
                let got = conv2d_q8(&qx, 1, a.in_c, h, w, &a, &qw.q, &ep);
                let acc = conv_acc_ref(&qx, h, w, &a, &qw.q);
                let (oh, ow) = a.out_hw(h, w);
                for oc in 0..a.out_c {
                    for i in 0..oh * ow {
                        let want = fix_requant1(
                            acc[oc * oh * ow + i],
                            mult[oc],
                            shift[oc],
                            bfx[oc],
                            lo,
                        );
                        assert_eq!(got[oc * oh * ow + i], want, "attrs {a:?} oc={oc} i={i}");
                    }
                }
                // Region splits over the i8 output are bit-identical.
                let mut split = vec![0i8; a.out_c * oh * ow];
                for (c0, c1, y0, y1) in
                    [(0, 2, 0, oh), (2, a.out_c, 0, 3), (2, a.out_c, 3, oh)]
                {
                    unsafe {
                        conv2d_region_raw_q8(
                            &qx, a.in_c, h, w, &a, &qw.q, &ep, c0, c1, y0, y1, 0, ow, oh, ow,
                            split.as_mut_ptr(),
                        )
                    };
                }
                assert_eq!(split, got, "attrs {a:?} lo={lo}");
            }
        }
    }

    #[test]
    fn q8_matmul_matches_integer_reference_and_column_splits() {
        let mut rng = Rng::new(52);
        let (m, k, n) = (7usize, 33usize, 19usize);
        let a: Vec<i8> = quantize_slice(&rng.vec_uniform(m * k), scale_for(1.0));
        let b: Vec<i8> = quantize_slice(&rng.vec_uniform(k * n), scale_for(1.0));
        let (sa, sb) = (0.013f32, 0.02f32);
        let rs = [sa];
        let cs = [sb];
        let ep = DeqF32 { row_scale: &rs, col_scale: &cs, row_bias: &[], col_bias: &[] };
        let full = matmul_q8(&a, m, k, &b, n, &ep);
        // Integer reference.
        for i in 0..m {
            for j in 0..n {
                let mut acc: i64 = 0;
                for kk in 0..k {
                    acc += a[i * k + kk] as i64 * b[kk * n + j] as i64;
                }
                assert_eq!(full[i * n + j], acc as i32 as f32 * sa * sb);
            }
        }
        // Column splits are bit-identical.
        let mut split = vec![0.0f32; m * n];
        for (j0, j1) in [(0usize, 5usize), (5, 12), (12, 19)] {
            unsafe { matmul_panel_raw_q8(&a, m, k, &b, n, j0, j1, &ep, split.as_mut_ptr()) };
        }
        assert_eq!(full, split);
    }

    #[test]
    fn q8_fc_applies_per_column_scales_and_bias() {
        let mut rng = Rng::new(53);
        let (rows, k, n) = (3usize, 10usize, 6usize);
        let x = rng.vec_uniform(rows * k);
        let sx = scale_for(1.0);
        let qa = quantize_slice(&x, sx);
        let w = rng.vec_uniform(k * n);
        let qw = QWeights::per_col(&w, k, n);
        let bias = rng.vec_uniform(n);
        let dq: Vec<f32> = qw.scale.iter().map(|&s| sx * s).collect();
        let rs = [1.0f32];
        let ep = DeqF32 { row_scale: &rs, col_scale: &dq, row_bias: &[], col_bias: &bias };
        let got = fc_q8(&qa, rows, k, n, &qw.q, &ep);
        for i in 0..rows {
            for j in 0..n {
                let mut acc: i64 = 0;
                for kk in 0..k {
                    acc += qa[i * k + kk] as i64 * qw.q[kk * n + j] as i64;
                }
                let want = acc as i32 as f32 * 1.0 * dq[j] + bias[j];
                assert_eq!(got[i * n + j], want, "({i},{j})");
            }
        }
    }

    #[test]
    fn fc_fixed_epilogue_indexes_per_column() {
        // by_col epilogues pick multiplier j for output column j — the FC
        // layout — and column splits stay bit-identical.
        let mut rng = Rng::new(55);
        let (rows, k, n) = (4usize, 12usize, 7usize);
        let qa = quantize_slice(&rng.vec_uniform(rows * k), scale_for(1.0));
        let w = rng.vec_uniform(k * n);
        let qw = QWeights::per_col(&w, k, n);
        let s_out = scale_for(3.0);
        let mut mult = Vec::new();
        let mut shift = Vec::new();
        let mut bfx = Vec::new();
        for j in 0..n {
            let (m, s) = fix_multiplier(qw.scale[j] / s_out);
            mult.push(m);
            shift.push(s);
            bfx.push(fix_bias(0.1 * j as f32, s));
        }
        let ep = FixedQ8 { mult: &mult, shift: &shift, bias: &bfx, lo: -127, by_col: true };
        let full = fc_q8(&qa, rows, k, n, &qw.q, &ep);
        for i in 0..rows {
            for j in 0..n {
                let mut acc: i64 = 0;
                for kk in 0..k {
                    acc += qa[i * k + kk] as i64 * qw.q[kk * n + j] as i64;
                }
                let want = fix_requant1(acc as i32, mult[j], shift[j], bfx[j], -127);
                assert_eq!(full[i * n + j], want, "({i},{j})");
            }
        }
        let mut split = vec![0i8; rows * n];
        for (j0, j1) in [(0usize, 3usize), (3, 7)] {
            unsafe { matmul_panel_raw_q8(&qa, rows, k, &qw.q, n, j0, j1, &ep, split.as_mut_ptr()) };
        }
        assert_eq!(split, full);
    }

    #[test]
    fn saturated_inputs_cannot_overflow_i32() {
        // Adversarial case: every operand saturated at ±127 over the
        // largest reduction in the zoo (2048·3·3) stays far below i32::MAX,
        // and the kernel reproduces the exact integer sum.
        let k = 2048 * 9;
        let qa = vec![127i8; k];
        let qb = vec![-127i8; k]; // [k, 1]
        let ep = DeqF32 { row_scale: &UNIT, col_scale: &UNIT, row_bias: &[], col_bias: &[] };
        let got = matmul_q8(&qa, 1, k, &qb, 1, &ep);
        let want = -(127i64 * 127 * k as i64);
        assert!(want.abs() < i32::MAX as i64);
        assert_eq!(got[0], want as i32 as f32);
    }
}
