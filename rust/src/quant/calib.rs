//! Calibration: per-channel activation ranges collected from
//! representative f32 runs, serialized alongside the model.
//!
//! The table drives *static* quantization — every engine reads its
//! activation scales from here instead of inspecting live data, which is
//! what makes serial, worker-pool and cluster execution quantize (and thus
//! compute) bit-identically. Collection itself is deterministic: the same
//! calibration inputs produce a byte-identical table
//! (`tests/quant.rs::calibration_is_deterministic`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::scale_for;
use crate::graph::{Graph, NodeId, OpKind};
use crate::ops::interp::{run_graph, synthetic_inputs};
use crate::ops::params::ParamStore;
use crate::ops::Tensor;

/// Per-channel symmetric activation ranges for every node of one model.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibTable {
    /// Zoo model name the table was collected for.
    pub model: String,
    /// Per node (indexed by `NodeId`): max-abs per channel for feature
    /// maps, a single per-tensor entry otherwise.
    pub per_channel: Vec<Vec<f32>>,
}

/// Max-abs per channel of one activation (one entry for non-FM tensors).
fn channel_ranges(t: &Tensor) -> Vec<f32> {
    let s = t.shape();
    if s.is_fm() {
        let (n, c, h, w) = (s.n(), s.c(), s.h(), s.w());
        let hw = h * w;
        let mut m = vec![0.0f32; c];
        for b in 0..n {
            for (ch, mc) in m.iter_mut().enumerate() {
                let base = (b * c + ch) * hw;
                for &v in &t.data[base..base + hw] {
                    *mc = mc.max(v.abs());
                }
            }
        }
        m
    } else {
        vec![t.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))]
    }
}

fn fold_max(into: &mut Vec<f32>, ranges: Vec<f32>) {
    if into.is_empty() {
        *into = ranges;
    } else {
        for (a, b) in into.iter_mut().zip(ranges) {
            *a = a.max(b);
        }
    }
}

impl CalibTable {
    /// Collect a table by running every calibration input set through the
    /// serial interpreter and folding per-channel max-abs across runs.
    pub fn collect(g: &Graph, params: &ParamStore, calib_inputs: &[Vec<Tensor>]) -> CalibTable {
        assert!(!calib_inputs.is_empty(), "calibration needs at least one input set");
        let mut per_channel: Vec<Vec<f32>> = vec![Vec::new(); g.len()];
        let input_ids = g.input_ids();
        for inputs in calib_inputs {
            for (&id, t) in input_ids.iter().zip(inputs) {
                fold_max(&mut per_channel[id], channel_ranges(t));
            }
            let _ = run_graph(
                g,
                inputs,
                |n, args| {
                    let out = crate::ops::interp::exec_node(params.get_ref(n.id), &n.op, args);
                    fold_max(&mut per_channel[n.id], channel_ranges(&out));
                    out
                },
                |_| {},
            );
        }
        // Nodes never executed (there are none today; inputs are recorded
        // above) would keep an empty range and decode to unit scales.
        CalibTable { model: g.name.clone(), per_channel }
    }

    /// Collect from `n` deterministic synthetic input sets (seeds
    /// `seed..seed+n`) — the in-repo stand-in for a representative
    /// dataset, matching how parameters and test inputs are synthesized.
    pub fn synthetic(g: &Graph, params: &ParamStore, n: usize, seed: u64) -> CalibTable {
        let sets: Vec<Vec<Tensor>> =
            (0..n.max(1) as u64).map(|i| synthetic_inputs(g, seed + i)).collect();
        Self::collect(g, params, &sets)
    }

    /// The per-tensor symmetric activation scale of one node: its widest
    /// channel range on the i8 grid.
    pub fn act_scale(&self, id: NodeId) -> f32 {
        let m = self.per_channel[id].iter().fold(0.0f32, |a, v| a.max(*v));
        scale_for(m)
    }

    /// Serialize (little-endian, self-describing header).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        push_u32(&mut out, self.model.len() as u32);
        out.extend_from_slice(self.model.as_bytes());
        push_u32(&mut out, self.per_channel.len() as u32);
        for ranges in &self.per_channel {
            push_u32(&mut out, ranges.len() as u32);
            for &v in ranges {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Decode a serialized table.
    pub fn decode(bytes: &[u8]) -> Result<CalibTable> {
        let mut cur = Cursor { buf: bytes, pos: 0 };
        let magic = cur.take(MAGIC.len())?;
        if magic != MAGIC {
            bail!("not a calibration table (bad magic)");
        }
        let mlen = cur.u32()? as usize;
        let model = String::from_utf8(cur.take(mlen)?.to_vec())
            .context("calibration model name is not UTF-8")?;
        let nodes = cur.u32()? as usize;
        let mut per_channel = Vec::with_capacity(nodes);
        for _ in 0..nodes {
            let c = cur.u32()? as usize;
            let mut ranges = Vec::with_capacity(c);
            for _ in 0..c {
                ranges.push(f32::from_le_bytes(cur.take(4)?.try_into().unwrap()));
            }
            per_channel.push(ranges);
        }
        Ok(CalibTable { model, per_channel })
    }

    /// Write the serialized table to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.encode())
            .with_context(|| format!("writing calibration table {}", path.display()))
    }

    /// Load a table from a file.
    pub fn load(path: &Path) -> Result<CalibTable> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading calibration table {}", path.display()))?;
        Self::decode(&bytes)
    }

    /// Sanity-check the table against a graph before use.
    pub fn matches(&self, g: &Graph) -> Result<()> {
        anyhow::ensure!(
            self.model == g.name,
            "calibration table is for model {}, graph is {}",
            self.model,
            g.name
        );
        anyhow::ensure!(
            self.per_channel.len() == g.len(),
            "calibration table covers {} nodes, graph {} has {}",
            self.per_channel.len(),
            g.name,
            g.len()
        );
        for n in &g.nodes {
            if n.out.shape.is_fm() && !matches!(n.op, OpKind::Input) {
                let want = n.out.shape.c();
                let got = self.per_channel[n.id].len();
                anyhow::ensure!(
                    got == want,
                    "node {} expects {want} channel ranges, table has {got}",
                    n.name
                );
            }
        }
        Ok(())
    }
}

const MAGIC: &[u8] = b"XQC1";

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated calibration table: need {n} bytes at {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Shape};

    fn small() -> Graph {
        let mut b = GraphBuilder::new("calib_t");
        let x = b.input("x", Shape::nchw(1, 2, 6, 6));
        let c = b.conv("c", x, 4, 3, 1, 1);
        let r = b.relu("r", c);
        b.output(r);
        b.finish()
    }

    #[test]
    fn collect_covers_every_node_per_channel() {
        let g = small();
        let p = ParamStore::for_graph(&g);
        let t = CalibTable::synthetic(&g, &p, 3, 7);
        assert_eq!(t.per_channel.len(), g.len());
        assert_eq!(t.per_channel[0].len(), 2); // input channels
        assert_eq!(t.per_channel[1].len(), 4); // conv out channels
        assert_eq!(t.per_channel[2].len(), 4);
        assert!(t.act_scale(1) > 0.0);
        t.matches(&g).unwrap();
    }

    #[test]
    fn relu_ranges_never_exceed_producer() {
        let g = small();
        let p = ParamStore::for_graph(&g);
        let t = CalibTable::synthetic(&g, &p, 2, 3);
        for (a, b) in t.per_channel[2].iter().zip(&t.per_channel[1]) {
            assert!(a <= b, "relu range above its input");
        }
    }

    #[test]
    fn encode_decode_roundtrips() {
        let g = small();
        let p = ParamStore::for_graph(&g);
        let t = CalibTable::synthetic(&g, &p, 2, 9);
        let back = CalibTable::decode(&t.encode()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn decode_rejects_garbage_and_truncation() {
        assert!(CalibTable::decode(b"nope").is_err());
        let g = small();
        let p = ParamStore::for_graph(&g);
        let bytes = CalibTable::synthetic(&g, &p, 1, 1).encode();
        assert!(CalibTable::decode(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn mismatched_graph_is_rejected() {
        let g = small();
        let p = ParamStore::for_graph(&g);
        let t = CalibTable::synthetic(&g, &p, 1, 1);
        let other = {
            let mut b = GraphBuilder::new("other");
            let x = b.input("x", Shape::nchw(1, 2, 6, 6));
            let c = b.conv("c", x, 8, 3, 1, 1);
            b.output(c);
            b.finish()
        };
        assert!(t.matches(&other).is_err());
    }
}
