//! INT8 quantized inference — the fixed-point execution path the paper's
//! DSP targets natively favor (multi-core C66x DSPs run 8/16-bit MACs at a
//! multiple of their f32 rate).
//!
//! Design, mirroring the crate's determinism discipline:
//!
//! * **Static symmetric quantization.** A calibration pass ([`calib`])
//!   runs representative f32 inputs through the serial interpreter and
//!   records per-channel activation ranges; engines derive symmetric
//!   per-channel activation grids for feature maps (per-tensor for
//!   everything else) and per-output-channel scales per weight tensor. No
//!   scale is ever computed from live data, so every engine — serial,
//!   parallel, cluster shard — quantizes identically.
//! * **i8-resident activations.** Quantized values flow between operators
//!   as [`QTensor`]s — raw i8 codes plus their decode grid. Integer
//!   operators ([`crate::opt::quant::QuantKind::IntDot`]) consume and
//!   produce codes directly through the fused requantize epilogue in
//!   [`kernels`]; f32 is materialized only at dequantize boundaries
//!   (graph outputs and f32-computed operators). There is **no**
//!   i8→f32→i8 round-trip on an `IntDot → IntDot` edge — the
//!   [`exec::QuantRun`] counter `snap_roundtrips` pins this at zero.
//! * **Integer accumulation + fixed-point requantization.** The kernels
//!   in [`kernels`] accumulate `i8 × i8` products in `i32` and requantize
//!   with a per-output-channel fixed-point multiplier+shift
//!   ([`fix_requant1`]), so every (oc, oy, ox) tiling — worker-pool
//!   chunks, cluster shards — is bit-identical to the serial result *by
//!   arithmetic*, an even stronger guarantee than the f32 kernels'
//!   shared-loop-order argument.
//!
//! Precision is planned per node by [`crate::opt::quant`] (which edges
//! stay i8-resident and which get dequantize boundaries), executed by
//! [`exec::QuantEngine`] on one host and by the quantized mode of
//! [`crate::dist::exec::ShardWorker`] on a cluster.

pub mod calib;
pub mod exec;
pub mod kernels;

pub use calib::CalibTable;
pub use exec::{QuantEngine, QuantRun};

use crate::graph::{DType, TensorDesc};
use crate::ops::Tensor;

/// Numeric precision an engine executes at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// 32-bit float — the reference path.
    F32,
    /// Symmetric INT8 with i32 accumulation.
    Int8,
}

impl Precision {
    /// Parse a CLI spelling (`f32` | `int8`).
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" | "fp32" => Some(Precision::F32),
            "int8" | "i8" | "q8" => Some(Precision::Int8),
            _ => None,
        }
    }

    /// CLI/report label.
    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

/// The symmetric scale covering `[-max_abs, max_abs]` on the i8 grid.
/// A degenerate (never-activated) range maps to scale 1 so quantization
/// stays total.
#[inline]
pub fn scale_for(max_abs: f32) -> f32 {
    if max_abs > 0.0 && max_abs.is_finite() {
        max_abs / 127.0
    } else {
        1.0
    }
}

/// Quantize one value, saturated to `[-127, 127]` — the symmetric range,
/// so negation stays exact.
///
/// **Rounding mode (pinned):** round-to-nearest with **ties away from
/// zero** — `f32::round` semantics, so `+0.5·scale → +1` and
/// `-0.5·scale → -1`. Every other quantization site in the system (the
/// fixed-point kernel epilogue [`fix_requant1`], the cluster workers'
/// grid packing) reproduces exactly this mode; the boundary-value tests
/// below and in `kernels` pin it so the paths can never drift.
#[inline]
pub fn quant1(v: f32, scale: f32) -> i8 {
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// Dequantize one value.
#[inline]
pub fn dequant1(q: i8, scale: f32) -> f32 {
    q as f32 * scale
}

/// Snap one value onto the i8 grid of `scale`. Snapped values round-trip:
/// `quant1(snap1(v, s), s)` recovers the same `q` exactly, which is what
/// makes i8 activation payloads lossless.
#[inline]
pub fn snap1(v: f32, scale: f32) -> f32 {
    dequant1(quant1(v, scale), scale)
}

/// Quantize a slice with one scale.
pub fn quantize_slice(x: &[f32], scale: f32) -> Vec<i8> {
    x.iter().map(|&v| quant1(v, scale)).collect()
}

/// Dequantize a slice with one scale.
pub fn dequantize_slice(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| dequant1(v, scale)).collect()
}

/// Snap a slice in place.
pub fn snap_slice(x: &mut [f32], scale: f32) {
    for v in x.iter_mut() {
        *v = snap1(*v, scale);
    }
}

/// Scale lookup on an activation grid / per-channel scale vector: a
/// length-1 vector is uniform (per-tensor), anything longer indexes per
/// channel.
#[inline]
pub fn grid_scale(grid: &[f32], ch: usize) -> f32 {
    if grid.len() == 1 {
        grid[0]
    } else {
        grid[ch]
    }
}

// ---------------------------------------------------------------------
// Fixed-point requantization — the integer twin of `quant1`.
//
// An i32 accumulator becomes an i8 code through `q = clamp(round((acc *
// eff_scale) + eff_bias))` where `eff_scale`/`eff_bias` fold the input
// grid, the per-channel weight scale, any fused BatchNorm affine and the
// output grid. The kernels evaluate this in pure integer arithmetic:
// `eff_scale ≈ mult · 2^-shift` (i32 mantissa) and `eff_bias ≈ bias_fx ·
// 2^-shift` (i64), with [`fix_round`] reproducing `quant1`'s
// ties-away-from-zero rounding. Per-element and integer-exact, so every
// tiling/chunking/sharding of a kernel yields bit-identical codes.
// ---------------------------------------------------------------------

/// Largest shift [`fix_multiplier`] emits. Bounded so `acc·mult +
/// bias_fx` stays comfortably inside i64 (`|acc·mult| < 2^62`,
/// `|bias_fx| ≤ 2^61`).
pub(crate) const FIX_SHIFT_MAX: u8 = 46;

/// Decompose `scale` as `mult · 2^-shift` with `mult: i32` (sign carried
/// by `mult`) and `shift ∈ [1, FIX_SHIFT_MAX]`, maximizing mantissa
/// precision. Degenerate scales (0, non-finite) map to `(0, 1)`.
pub(crate) fn fix_multiplier(scale: f32) -> (i32, u8) {
    if scale == 0.0 || !scale.is_finite() {
        return (0, 1);
    }
    let a = scale.abs() as f64;
    let mut m = a;
    let mut e = 0i32;
    while m < 0.5 {
        m *= 2.0;
        e -= 1;
    }
    while m >= 1.0 {
        m /= 2.0;
        e += 1;
    }
    // a = m · 2^e with m ∈ [0.5, 1); mult = a · 2^shift ∈ [2^30, 2^31].
    let shift = (31 - e).clamp(1, FIX_SHIFT_MAX as i32);
    let mult = (a * (1u64 << shift) as f64).round().min(i32::MAX as f64) as i32;
    (if scale < 0.0 { -mult } else { mult }, shift as u8)
}

/// The fixed-point image of an f32 bias term at `2^-shift` precision,
/// saturated to ±2^61 so the kernel epilogue's i64 sum cannot overflow.
pub(crate) fn fix_bias(bias: f32, shift: u8) -> i64 {
    let lim = (1i64 << 61) as f64;
    (bias as f64 * (1u64 << shift) as f64).round().clamp(-lim, lim) as i64
}

/// Round `v · 2^-shift` to the nearest integer, **ties away from zero**
/// — the integer twin of `f32::round` as used by [`quant1`]. `shift`
/// must be ≥ 1.
#[inline]
pub(crate) fn fix_round(v: i64, shift: u8) -> i64 {
    let half = 1i64 << (shift - 1);
    if v >= 0 {
        (v + half) >> shift
    } else {
        -((-v + half) >> shift)
    }
}

/// Requantize one i32 accumulator to an i8 code: `clamp(round(acc·mult·
/// 2^-shift + bias·2^-shift), lo, 127)`. `lo = 0` realizes a fused ReLU
/// (clamping at zero *is* ReLU on a symmetric grid), `lo = -127`
/// otherwise.
#[inline]
pub fn fix_requant1(acc: i32, mult: i32, shift: u8, bias: i64, lo: i8) -> i8 {
    let v = acc as i64 * mult as i64 + bias;
    fix_round(v, shift).clamp(lo as i64, 127) as i8
}

/// An i8 tensor: quantized payload plus the grid that decodes it.
///
/// `scale` holds one entry for per-tensor quantization or one entry per
/// channel (feature-map activations, conv/FC weights); `desc.dtype` is
/// [`DType::I8`], so byte accounting through the simulator and the wire
/// sees the real 1-byte elements.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    pub desc: TensorDesc,
    pub data: Vec<i8>,
    /// Per-tensor (len 1) or per-channel decode scales.
    pub scale: Vec<f32>,
}

impl QTensor {
    /// Quantize a float tensor with one per-tensor scale.
    pub fn quantize(x: &Tensor, scale: f32) -> QTensor {
        Self::quantize_with(x, &[scale])
    }

    /// Quantize a float tensor onto a grid: per-channel when `grid` has
    /// one entry per feature-map channel, per-tensor when it has one.
    pub fn quantize_with(x: &Tensor, grid: &[f32]) -> QTensor {
        let mut desc = x.desc.clone();
        desc.dtype = DType::I8;
        let data = if grid.len() == 1 {
            quantize_slice(&x.data, grid[0])
        } else {
            let s = x.shape();
            assert!(s.is_fm(), "per-channel grid on a non-feature-map tensor");
            let (n, c, h, w) = (s.n(), s.c(), s.h(), s.w());
            assert_eq!(grid.len(), c, "grid length does not match channels");
            let hw = h * w;
            let mut out = Vec::with_capacity(x.data.len());
            for b in 0..n {
                for (ch, &sc) in grid.iter().enumerate() {
                    let base = (b * c + ch) * hw;
                    out.extend(x.data[base..base + hw].iter().map(|&v| quant1(v, sc)));
                }
            }
            out
        };
        QTensor { desc, data, scale: grid.to_vec() }
    }

    /// An all-zero code buffer on `grid` with the f32 `desc`'s shape —
    /// the starting point for kernels that fill disjoint regions.
    pub fn zeros(desc: TensorDesc, grid: Vec<f32>) -> QTensor {
        let mut desc = desc;
        desc.dtype = DType::I8;
        let n = desc.shape.numel();
        QTensor { desc, data: vec![0i8; n], scale: grid }
    }

    /// Wrap raw codes produced by a kernel epilogue.
    pub fn from_codes(desc: TensorDesc, data: Vec<i8>, grid: Vec<f32>) -> QTensor {
        let mut desc = desc;
        desc.dtype = DType::I8;
        debug_assert_eq!(desc.shape.numel(), data.len(), "code buffer size mismatch");
        QTensor { desc, data, scale: grid }
    }

    /// Decode back to f32 (per-tensor or per-channel grid).
    pub fn dequantize(&self) -> Tensor {
        let mut desc = self.desc.clone();
        desc.dtype = DType::F32;
        let data = if self.scale.len() == 1 {
            dequantize_slice(&self.data, self.scale[0])
        } else {
            let s = &self.desc.shape;
            let (n, c, h, w) = (s.n(), s.c(), s.h(), s.w());
            debug_assert_eq!(self.scale.len(), c, "grid length does not match channels");
            let hw = h * w;
            let mut out = Vec::with_capacity(self.data.len());
            for b in 0..n {
                for (ch, &sc) in self.scale.iter().enumerate() {
                    let base = (b * c + ch) * hw;
                    out.extend(self.data[base..base + hw].iter().map(|&q| dequant1(q, sc)));
                }
            }
            out
        };
        Tensor::new(desc, data)
    }

    /// The decoded shape (same as the f32 tensor's).
    pub fn shape(&self) -> &crate::graph::Shape {
        &self.desc.shape
    }

    /// Payload bytes (1 per element).
    pub fn bytes(&self) -> u64 {
        self.data.len() as u64
    }
}

/// Per-node quantized weights: i8 rows with one scale per output
/// channel (conv) or output column (FC). Per-channel scales make weight
/// shards self-contained — slicing the quantized rows equals quantizing
/// the sliced rows, which is why every d-Xenos rank can quantize its own
/// shard and still match the master bit-for-bit.
#[derive(Debug, Clone, Default)]
pub struct QWeights {
    /// Quantized weights, same element order as the f32 original.
    pub q: Vec<i8>,
    /// One scale per output channel/column. When the weights were folded
    /// with the input activation grid (see [`exec::QuantRun`]), this is
    /// the **complete** dequantization factor of an i32 accumulator.
    pub scale: Vec<f32>,
}

impl QWeights {
    /// Quantize conv-style weights `[rows, row_len]` (row = one output
    /// channel) with one symmetric scale per row.
    pub fn per_row(w: &[f32], rows: usize, row_len: usize) -> QWeights {
        assert_eq!(w.len(), rows * row_len, "weight shape mismatch");
        let mut q = Vec::with_capacity(w.len());
        let mut scale = Vec::with_capacity(rows);
        for r in 0..rows {
            let row = &w[r * row_len..(r + 1) * row_len];
            let s = scale_for(row.iter().fold(0.0f32, |m, v| m.max(v.abs())));
            scale.push(s);
            q.extend(row.iter().map(|&v| quant1(v, s)));
        }
        QWeights { q, scale }
    }

    /// Quantize FC-style weights `[k, n]` (row-major) with one symmetric
    /// scale per output *column*.
    pub fn per_col(w: &[f32], k: usize, n: usize) -> QWeights {
        assert_eq!(w.len(), k * n, "weight shape mismatch");
        let mut scale = vec![0.0f32; n];
        for kk in 0..k {
            for j in 0..n {
                scale[j] = scale[j].max(w[kk * n + j].abs());
            }
        }
        for s in scale.iter_mut() {
            *s = scale_for(*s);
        }
        let mut q = Vec::with_capacity(w.len());
        for kk in 0..k {
            for j in 0..n {
                q.push(quant1(w[kk * n + j], scale[j]));
            }
        }
        QWeights { q, scale }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;

    #[test]
    fn quantize_roundtrip_error_is_half_step() {
        let s = scale_for(2.0);
        for v in [-2.0f32, -1.3, -0.01, 0.0, 0.5, 1.999, 2.0] {
            let err = (snap1(v, s) - v).abs();
            assert!(err <= s / 2.0 + 1e-7, "v={v} err={err}");
        }
    }

    #[test]
    fn quantize_saturates_symmetrically() {
        let s = scale_for(1.0);
        assert_eq!(quant1(10.0, s), 127);
        assert_eq!(quant1(-10.0, s), -127);
        assert_eq!(quant1(1.0, s), 127);
        assert_eq!(quant1(-1.0, s), -127);
    }

    #[test]
    fn rounding_mode_is_ties_away_from_zero() {
        // The pinned mode: exact half-step inputs round away from zero.
        // (f32 `round`, not round-half-even — a drift here would silently
        // desynchronize the fixed-point kernel epilogue from `quant1`.)
        let s = 1.0f32; // ±0.5·scale inputs are exactly representable
        assert_eq!(quant1(0.5, s), 1);
        assert_eq!(quant1(-0.5, s), -1);
        assert_eq!(quant1(1.5, s), 2);
        assert_eq!(quant1(-1.5, s), -2);
        assert_eq!(quant1(0.25, s), 0);
        assert_eq!(quant1(-0.25, s), 0);
        // And at a non-unit scale with exactly representable half steps.
        let s = 0.25f32;
        assert_eq!(quant1(0.125, s), 1);
        assert_eq!(quant1(-0.125, s), -1);
    }

    #[test]
    fn fix_round_matches_f32_round_ties() {
        // fix_round(v, s) rounds v·2^-s with the same ties-away rule:
        // value k + 0.5 rounds to k+1 for k ≥ 0 and to k for k ≤ -1
        // (away from zero in both cases).
        for shift in [1u8, 4, 17, 31] {
            let one = 1i64 << shift;
            for k in -5i64..=5 {
                let tie = k * one + one / 2; // value = k + 0.5 exactly
                let want = if k >= 0 { k + 1 } else { k };
                assert_eq!(fix_round(tie, shift), want, "tie shift={shift} k={k}");
                // Just below / above the tie round to the nearest integer.
                assert_eq!(fix_round(tie - 1, shift), k, "below tie k={k}");
                assert_eq!(fix_round(tie + 1, shift), k + 1, "above tie k={k}");
            }
        }
    }

    #[test]
    fn fix_requant_tracks_f32_requant_within_one_code() {
        // The fixed-point epilogue reproduces clamp(round(acc·es + eb))
        // to within one code of the f64 reference over a dense sweep
        // (exact agreement away from representation boundaries).
        let mut rng = crate::util::rng::Rng::new(77);
        for _ in 0..200 {
            let es = (rng.vec_uniform(1)[0]) * 0.01; // eff scales, ± and tiny
            let eb = rng.vec_uniform(1)[0] * 3.0;
            let (mult, shift) = fix_multiplier(es);
            let bias = fix_bias(eb, shift);
            for acc in [-300_000i32, -1234, -1, 0, 1, 999, 250_000] {
                let got = fix_requant1(acc, mult, shift, bias, -127);
                let want = (acc as f64 * es as f64 + eb as f64)
                    .round()
                    .clamp(-127.0, 127.0) as i32;
                assert!(
                    (got as i32 - want).abs() <= 1,
                    "acc={acc} es={es} eb={eb}: fixed {got} vs f64 {want}"
                );
            }
        }
    }

    #[test]
    fn fix_multiplier_handles_degenerate_and_negative_scales() {
        assert_eq!(fix_multiplier(0.0), (0, 1));
        assert_eq!(fix_multiplier(f32::NAN), (0, 1));
        let (m, s) = fix_multiplier(-0.125);
        assert!(m < 0, "sign carried by the mantissa");
        let back = m as f64 / (1u64 << s) as f64;
        assert!((back + 0.125).abs() < 1e-9, "decomposition inverts: {back}");
        // relu clamp: lo = 0 suppresses negatives entirely.
        assert_eq!(fix_requant1(100, m, s, 0, 0), 0);
    }

    #[test]
    fn snapped_values_requantize_exactly() {
        let s = scale_for(3.7);
        for q in -127i32..=127 {
            let v = dequant1(q as i8, s);
            assert_eq!(quant1(v, s), q as i8, "q={q}");
        }
    }

    #[test]
    fn degenerate_range_has_unit_scale() {
        assert_eq!(scale_for(0.0), 1.0);
        assert_eq!(scale_for(f32::NAN), 1.0);
    }

    #[test]
    fn qtensor_roundtrip_shapes_and_dtype() {
        let x = Tensor::new(
            TensorDesc::plain(Shape::mat(2, 3)),
            vec![0.5, -0.25, 1.0, -1.0, 0.0, 0.75],
        );
        let q = QTensor::quantize(&x, scale_for(1.0));
        assert_eq!(q.desc.dtype, DType::I8);
        assert_eq!(q.bytes(), 6);
        let y = q.dequantize();
        assert_eq!(y.shape(), x.shape());
        assert!(x.max_abs_diff(&y) <= scale_for(1.0) / 2.0 + 1e-7);
    }

    #[test]
    fn per_channel_qtensor_roundtrips_each_channel_on_its_grid() {
        let x = Tensor::fm(1, 2, 2, 2, vec![0.5, -0.25, 1.0, -1.0, 4.0, -2.0, 8.0, 0.0]);
        let grid = vec![scale_for(1.0), scale_for(8.0)];
        let q = QTensor::quantize_with(&x, &grid);
        assert_eq!(q.scale, grid);
        let y = q.dequantize();
        for ch in 0..2 {
            for i in 0..4 {
                let idx = ch * 4 + i;
                assert!(
                    (y.data[idx] - x.data[idx]).abs() <= grid[ch] / 2.0 + 1e-6,
                    "ch={ch} i={i}"
                );
            }
        }
        // Snapped values recover their codes exactly, per channel.
        let q2 = QTensor::quantize_with(&y, &grid);
        assert_eq!(q.data, q2.data);
    }

    #[test]
    fn per_row_weight_scales_cover_each_row() {
        let w = vec![1.0, -2.0, 0.5, 0.25]; // rows [1,-2], [0.5,0.25]
        let qw = QWeights::per_row(&w, 2, 2);
        assert_eq!(qw.scale.len(), 2);
        assert!((qw.scale[0] - 2.0 / 127.0).abs() < 1e-9);
        assert_eq!(qw.q[1], -127);
        assert_eq!(qw.q[2], 127); // 0.5 at scale 0.5/127
    }

    #[test]
    fn per_col_matches_column_slicing() {
        // Quantizing a column slice equals slicing the quantized matrix —
        // the property FC weight shards rely on.
        let (k, n) = (3usize, 4usize);
        let mut rng = crate::util::rng::Rng::new(40);
        let w = rng.vec_uniform(k * n);
        let full = QWeights::per_col(&w, k, n);
        let (j0, j1) = (1usize, 3usize);
        let mut sliced = Vec::new();
        for kk in 0..k {
            sliced.extend_from_slice(&w[kk * n + j0..kk * n + j1]);
        }
        let sub = QWeights::per_col(&sliced, k, j1 - j0);
        assert_eq!(sub.scale, full.scale[j0..j1]);
        for kk in 0..k {
            assert_eq!(
                &sub.q[kk * (j1 - j0)..(kk + 1) * (j1 - j0)],
                &full.q[kk * n + j0..kk * n + j1]
            );
        }
    }
}
