//! INT8 quantized inference — the fixed-point execution path the paper's
//! DSP targets natively favor (multi-core C66x DSPs run 8/16-bit MACs at a
//! multiple of their f32 rate).
//!
//! Design, mirroring the crate's determinism discipline:
//!
//! * **Static symmetric quantization.** A calibration pass ([`calib`])
//!   runs representative f32 inputs through the serial interpreter and
//!   records per-channel activation ranges; engines derive one symmetric
//!   per-tensor scale per activation and per-output-channel scales per
//!   weight tensor. No scale is ever computed from live data, so every
//!   engine — serial, parallel, cluster shard — quantizes identically.
//! * **Grid-snapped activations.** Every quantized node's f32 output is
//!   *snapped* to its i8 grid (`dequant(quant(x))`): the value that flows
//!   along an edge is exactly representable as `q * scale` with `q ∈
//!   [-127, 127]`. Re-quantizing a snapped value recovers `q` exactly, so
//!   the d-Xenos runtime ships raw i8 halo/all-gather payloads
//!   (`dist::exec`) with **zero additional error** — a 4× cut in
//!   activation traffic, the DEFER observation applied to this runtime.
//! * **Integer accumulation.** The kernels in [`kernels`] accumulate
//!   `i8 × i8` products in `i32`. Integer sums are exact under any
//!   evaluation order, so every (oc, oy, ox) tiling — worker-pool chunks,
//!   cluster shards — is bit-identical to the serial result *by
//!   arithmetic*, an even stronger guarantee than the f32 kernels'
//!   shared-loop-order argument.
//!
//! Precision is planned per node by [`crate::opt::quant`] (which
//! quantize/dequantize boundaries exist and which fold away), executed by
//! [`exec::QuantEngine`] on one host and by the quantized mode of
//! [`crate::dist::exec::ShardWorker`] on a cluster.

pub mod calib;
pub mod exec;
pub mod kernels;

pub use calib::CalibTable;
pub use exec::{QuantEngine, QuantRun};

use crate::graph::{DType, TensorDesc};
use crate::ops::Tensor;

/// Numeric precision an engine executes at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// 32-bit float — the reference path.
    F32,
    /// Symmetric INT8 with i32 accumulation.
    Int8,
}

impl Precision {
    /// Parse a CLI spelling (`f32` | `int8`).
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" | "fp32" => Some(Precision::F32),
            "int8" | "i8" | "q8" => Some(Precision::Int8),
            _ => None,
        }
    }

    /// CLI/report label.
    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

/// The symmetric scale covering `[-max_abs, max_abs]` on the i8 grid.
/// A degenerate (never-activated) range maps to scale 1 so quantization
/// stays total.
#[inline]
pub fn scale_for(max_abs: f32) -> f32 {
    if max_abs > 0.0 && max_abs.is_finite() {
        max_abs / 127.0
    } else {
        1.0
    }
}

/// Quantize one value: round-to-nearest (ties away from zero), saturated
/// to `[-127, 127]` — the symmetric range, so negation stays exact.
#[inline]
pub fn quant1(v: f32, scale: f32) -> i8 {
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// Dequantize one value.
#[inline]
pub fn dequant1(q: i8, scale: f32) -> f32 {
    q as f32 * scale
}

/// Snap one value onto the i8 grid of `scale`. Snapped values round-trip:
/// `quant1(snap1(v, s), s)` recovers the same `q` exactly, which is what
/// makes i8 activation payloads lossless.
#[inline]
pub fn snap1(v: f32, scale: f32) -> f32 {
    dequant1(quant1(v, scale), scale)
}

/// Quantize a slice with one scale.
pub fn quantize_slice(x: &[f32], scale: f32) -> Vec<i8> {
    x.iter().map(|&v| quant1(v, scale)).collect()
}

/// Dequantize a slice with one scale.
pub fn dequantize_slice(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| dequant1(v, scale)).collect()
}

/// Snap a slice in place.
pub fn snap_slice(x: &mut [f32], scale: f32) {
    for v in x.iter_mut() {
        *v = snap1(*v, scale);
    }
}

/// An i8 tensor: quantized payload plus the scales that decode it.
///
/// `scale` holds one entry for per-tensor quantization (activations) or
/// one entry per output channel (conv/FC weights); `desc.dtype` is
/// [`DType::I8`], so byte accounting through the simulator and the wire
/// sees the real 1-byte elements.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    pub desc: TensorDesc,
    pub data: Vec<i8>,
    /// Per-tensor (len 1) or per-channel decode scales.
    pub scale: Vec<f32>,
}

impl QTensor {
    /// Quantize a float tensor with one per-tensor scale.
    pub fn quantize(x: &Tensor, scale: f32) -> QTensor {
        let mut desc = x.desc.clone();
        desc.dtype = DType::I8;
        QTensor { desc, data: quantize_slice(&x.data, scale), scale: vec![scale] }
    }

    /// Decode back to f32 (per-tensor scale only).
    pub fn dequantize(&self) -> Tensor {
        assert_eq!(self.scale.len(), 1, "per-channel QTensor needs a channel-aware decoder");
        let mut desc = self.desc.clone();
        desc.dtype = DType::F32;
        Tensor::new(desc, dequantize_slice(&self.data, self.scale[0]))
    }

    /// Payload bytes (1 per element).
    pub fn bytes(&self) -> u64 {
        self.data.len() as u64
    }
}

/// Per-node quantized weights: i8 rows with one scale per output
/// channel (conv) or output column (FC). Per-channel scales make weight
/// shards self-contained — slicing the quantized rows equals quantizing
/// the sliced rows, which is why every d-Xenos rank can quantize its own
/// shard and still match the master bit-for-bit.
#[derive(Debug, Clone, Default)]
pub struct QWeights {
    /// Quantized weights, same element order as the f32 original.
    pub q: Vec<i8>,
    /// One scale per output channel/column.
    pub scale: Vec<f32>,
}

impl QWeights {
    /// Quantize conv-style weights `[rows, row_len]` (row = one output
    /// channel) with one symmetric scale per row.
    pub fn per_row(w: &[f32], rows: usize, row_len: usize) -> QWeights {
        assert_eq!(w.len(), rows * row_len, "weight shape mismatch");
        let mut q = Vec::with_capacity(w.len());
        let mut scale = Vec::with_capacity(rows);
        for r in 0..rows {
            let row = &w[r * row_len..(r + 1) * row_len];
            let s = scale_for(row.iter().fold(0.0f32, |m, v| m.max(v.abs())));
            scale.push(s);
            q.extend(row.iter().map(|&v| quant1(v, s)));
        }
        QWeights { q, scale }
    }

    /// Quantize FC-style weights `[k, n]` (row-major) with one symmetric
    /// scale per output *column*.
    pub fn per_col(w: &[f32], k: usize, n: usize) -> QWeights {
        assert_eq!(w.len(), k * n, "weight shape mismatch");
        let mut scale = vec![0.0f32; n];
        for kk in 0..k {
            for j in 0..n {
                scale[j] = scale[j].max(w[kk * n + j].abs());
            }
        }
        for s in scale.iter_mut() {
            *s = scale_for(*s);
        }
        let mut q = Vec::with_capacity(w.len());
        for kk in 0..k {
            for j in 0..n {
                q.push(quant1(w[kk * n + j], scale[j]));
            }
        }
        QWeights { q, scale }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;

    #[test]
    fn quantize_roundtrip_error_is_half_step() {
        let s = scale_for(2.0);
        for v in [-2.0f32, -1.3, -0.01, 0.0, 0.5, 1.999, 2.0] {
            let err = (snap1(v, s) - v).abs();
            assert!(err <= s / 2.0 + 1e-7, "v={v} err={err}");
        }
    }

    #[test]
    fn quantize_saturates_symmetrically() {
        let s = scale_for(1.0);
        assert_eq!(quant1(10.0, s), 127);
        assert_eq!(quant1(-10.0, s), -127);
        assert_eq!(quant1(1.0, s), 127);
        assert_eq!(quant1(-1.0, s), -127);
    }

    #[test]
    fn snapped_values_requantize_exactly() {
        let s = scale_for(3.7);
        for q in -127i32..=127 {
            let v = dequant1(q as i8, s);
            assert_eq!(quant1(v, s), q as i8, "q={q}");
        }
    }

    #[test]
    fn degenerate_range_has_unit_scale() {
        assert_eq!(scale_for(0.0), 1.0);
        assert_eq!(scale_for(f32::NAN), 1.0);
    }

    #[test]
    fn qtensor_roundtrip_shapes_and_dtype() {
        let x = Tensor::new(
            TensorDesc::plain(Shape::mat(2, 3)),
            vec![0.5, -0.25, 1.0, -1.0, 0.0, 0.75],
        );
        let q = QTensor::quantize(&x, scale_for(1.0));
        assert_eq!(q.desc.dtype, DType::I8);
        assert_eq!(q.bytes(), 6);
        let y = q.dequantize();
        assert_eq!(y.shape(), x.shape());
        assert!(x.max_abs_diff(&y) <= scale_for(1.0) / 2.0 + 1e-7);
    }

    #[test]
    fn per_row_weight_scales_cover_each_row() {
        let w = vec![1.0, -2.0, 0.5, 0.25]; // rows [1,-2], [0.5,0.25]
        let qw = QWeights::per_row(&w, 2, 2);
        assert_eq!(qw.scale.len(), 2);
        assert!((qw.scale[0] - 2.0 / 127.0).abs() < 1e-9);
        assert_eq!(qw.q[1], -127);
        assert_eq!(qw.q[2], 127); // 0.5 at scale 0.5/127
    }

    #[test]
    fn per_col_matches_column_slicing() {
        // Quantizing a column slice equals slicing the quantized matrix —
        // the property FC weight shards rely on.
        let (k, n) = (3usize, 4usize);
        let mut rng = crate::util::rng::Rng::new(40);
        let w = rng.vec_uniform(k * n);
        let full = QWeights::per_col(&w, k, n);
        let (j0, j1) = (1usize, 3usize);
        let mut sliced = Vec::new();
        for kk in 0..k {
            sliced.extend_from_slice(&w[kk * n + j0..kk * n + j1]);
        }
        let sub = QWeights::per_col(&sliced, k, j1 - j0);
        assert_eq!(sub.scale, full.scale[j0..j1]);
        for kk in 0..k {
            assert_eq!(
                &sub.q[kk * (j1 - j0)..(kk + 1) * (j1 - j0)],
                &full.q[kk * n + j0..kk * n + j1]
            );
        }
    }
}
