//! Minimal property-based testing framework.
//!
//! `proptest` is not available in the offline vendored dependency set, so
//! this module provides the subset the test suites need: composable
//! generators over the deterministic [`Rng`](crate::util::rng::Rng) and a
//! `forall` runner that reports the failing seed/case on panic. No
//! shrinking — failing inputs are printed verbatim and reproducible from
//! the seed.

use crate::util::rng::Rng;

/// A value generator.
pub trait Gen {
    /// Generated type.
    type Item;
    /// Draw one value.
    fn gen(&self, rng: &mut Rng) -> Self::Item;
}

/// Uniform integer range `[lo, hi]` inclusive.
pub struct IntRange {
    /// Lower bound (inclusive).
    pub lo: usize,
    /// Upper bound (inclusive).
    pub hi: usize,
}

impl Gen for IntRange {
    type Item = usize;
    fn gen(&self, rng: &mut Rng) -> usize {
        rng.usize_range(self.lo, self.hi)
    }
}

/// Choose uniformly from a fixed slice.
pub struct Choice<T: Clone>(pub Vec<T>);

impl<T: Clone> Gen for Choice<T> {
    type Item = T;
    fn gen(&self, rng: &mut Rng) -> T {
        self.0[rng.usize_below(self.0.len())].clone()
    }
}

/// Uniform f32 in `[lo, hi)`.
pub struct FloatRange {
    /// Lower bound.
    pub lo: f32,
    /// Upper bound.
    pub hi: f32,
}

impl Gen for FloatRange {
    type Item = f32;
    fn gen(&self, rng: &mut Rng) -> f32 {
        rng.f32_range(self.lo, self.hi)
    }
}

/// Vector of `n` draws from an inner generator.
pub struct VecOf<G: Gen> {
    /// Element generator.
    pub inner: G,
    /// Length generator bounds.
    pub len: IntRange,
}

impl<G: Gen> Gen for VecOf<G> {
    type Item = Vec<G::Item>;
    fn gen(&self, rng: &mut Rng) -> Vec<G::Item> {
        let n = self.len.gen(rng);
        (0..n).map(|_| self.inner.gen(rng)).collect()
    }
}

/// Functional generator from a closure.
pub struct FnGen<T, F: Fn(&mut Rng) -> T>(pub F);

impl<T, F: Fn(&mut Rng) -> T> Gen for FnGen<T, F> {
    type Item = T;
    fn gen(&self, rng: &mut Rng) -> T {
        (self.0)(rng)
    }
}

/// Run `prop` on `cases` generated inputs. On failure, panics with the
/// case index and seed so the exact input is reproducible.
pub fn forall<G: Gen>(seed: u64, cases: usize, gen: &G, prop: impl Fn(G::Item))
where
    G::Item: std::fmt::Debug + Clone,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.gen(&mut rng);
        let snapshot = input.clone();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(input)));
        if let Err(e) = result {
            crate::xerror!(
                "testkit: property failed at case {case} (seed {seed}), input: {snapshot:?}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_range_respects_bounds() {
        forall(1, 500, &IntRange { lo: 3, hi: 17 }, |v| {
            assert!((3..=17).contains(&v));
        });
    }

    #[test]
    fn choice_draws_members() {
        let g = Choice(vec!["a", "b", "c"]);
        forall(2, 200, &g, |v| assert!(["a", "b", "c"].contains(&v)));
    }

    #[test]
    fn vec_of_bounds_length() {
        let g = VecOf { inner: FloatRange { lo: -1.0, hi: 1.0 }, len: IntRange { lo: 1, hi: 9 } };
        forall(3, 100, &g, |v| {
            assert!((1..=9).contains(&v.len()));
            assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
        });
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failures() {
        forall(4, 50, &IntRange { lo: 0, hi: 100 }, |v| {
            assert!(v < 90, "intentional failure");
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let g = IntRange { lo: 0, hi: 1000 };
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(g.gen(&mut a), g.gen(&mut b));
        }
    }
}
