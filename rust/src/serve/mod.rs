//! Serving coordinator — the paper's §2.1 inference workflow as a
//! production-shaped request loop.
//!
//! Architecture (Python never appears; engines execute AOT artifacts):
//!
//! ```text
//!  acquisition ──> preprocess ──> router ──> dynamic batcher ──> workers
//!  (synthetic      (normalize,    (queue,     (max_batch /        (Engine:
//!   image source)   resize)        backpressure) max_wait)         PJRT)
//! ```
//!
//! * [`batcher`] — size/deadline dynamic batching.
//! * [`pipeline`] — the three-stage §2.1 pipeline with per-stage timing
//!   (reproduces "the inference module takes over 60% of the overall
//!   execution time").
//! * [`coordinator`] — router + worker pool + metrics.

pub mod batcher;
pub mod coordinator;
pub mod pipeline;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use coordinator::{Coordinator, ServeConfig, ServeReport};
pub use pipeline::{run_pipeline, PipelineConfig, PipelineReport};

use crate::ops::Tensor;
use std::time::Instant;

/// One inference request.
#[derive(Debug)]
pub struct Request {
    /// Caller-assigned id; responses carry it back.
    pub id: u64,
    /// Model inputs.
    pub inputs: Vec<Tensor>,
    /// Submission timestamp (latency measurement).
    pub submitted: Instant,
}

/// One inference response.
#[derive(Debug)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Model outputs.
    pub outputs: Vec<Tensor>,
    /// End-to-end latency (submit → response), seconds.
    pub latency_s: f64,
    /// Pure engine execution time for the **whole batch** this request
    /// was served in, seconds (the batch is one engine call; divide by
    /// `batch_size` for the per-sample amortized cost).
    pub exec_s: f64,
    /// Time queued before the batcher pulled the request, seconds.
    pub queue_s: f64,
    /// Time held in an open batch waiting for it to form, seconds.
    pub assembly_s: f64,
    /// Batch size the request was served in.
    pub batch_size: usize,
    /// Worker that served it.
    pub worker: usize,
}
