//! Serving coordinator — the paper's §2.1 inference workflow as a
//! production-shaped request loop.
//!
//! Architecture (Python never appears; engines execute AOT artifacts):
//!
//! ```text
//!  acquisition ──> preprocess ──> router ──> dynamic batcher ──> workers
//!  (synthetic      (normalize,    (queue,     (max_batch /        (Engine:
//!   image source)   resize)        backpressure) max_wait)         PJRT)
//! ```
//!
//! * [`batcher`] — size/deadline dynamic batching.
//! * [`pipeline`] — the three-stage §2.1 pipeline with per-stage timing
//!   (reproduces "the inference module takes over 60% of the overall
//!   execution time").
//! * [`coordinator`] — router + worker pool + metrics.
//! * [`ingest`] — the TCP front door's wire format (request/output/
//!   error/busy frames).
//! * [`server`] — the network listener: multi-model registry, bounded
//!   admission, load shedding, deadlines, graceful drain.
//! * [`client`] — frame-level client + load driver for tests, benches,
//!   and the `xenos client` verb.

pub mod batcher;
pub mod client;
pub mod coordinator;
pub mod ingest;
pub mod pipeline;
pub mod server;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use client::{IngestClient, LoadReport, Terminal};
pub use coordinator::{Coordinator, ServeConfig, ServeReport};
pub use pipeline::{run_pipeline, PipelineConfig, PipelineReport};
pub use server::{IngestConfig, IngestServer, IngestStats, ModelRegistry};

use crate::ops::Tensor;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// One inference request.
#[derive(Debug)]
pub struct Request {
    /// Caller-assigned id; responses carry it back.
    pub id: u64,
    /// Model inputs.
    pub inputs: Vec<Tensor>,
    /// Submission timestamp (latency measurement).
    pub submitted: Instant,
}

/// One inference response.
#[derive(Debug)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Model outputs.
    pub outputs: Vec<Tensor>,
    /// End-to-end latency (submit → response), seconds.
    pub latency_s: f64,
    /// Pure engine execution time for the **whole batch** this request
    /// was served in, seconds (the batch is one engine call; divide by
    /// `batch_size` for the per-sample amortized cost).
    pub exec_s: f64,
    /// Time queued before the batcher pulled the request, seconds.
    pub queue_s: f64,
    /// Time held in an open batch waiting for it to form, seconds.
    pub assembly_s: f64,
    /// Batch size the request was served in.
    pub batch_size: usize,
    /// Worker that served it.
    pub worker: usize,
}

/// Index of the least-loaded worker, breaking ties by scanning from
/// `rotate % counts.len()` — callers bump `rotate` every dispatch so that
/// under low load (all counts equal) work round-robins instead of piling
/// onto rank 0. Relaxed loads suffice: counts are advisory routing hints,
/// not synchronization.
pub(crate) fn pick_least_loaded(counts: &[AtomicUsize], rotate: usize) -> usize {
    let n = counts.len();
    assert!(n > 0, "at least one worker");
    let start = rotate % n;
    let mut best = start;
    let mut best_load = counts[start].load(Ordering::Relaxed);
    for off in 1..n {
        let i = (start + off) % n;
        let load = counts[i].load(Ordering::Relaxed);
        if load < best_load {
            best = i;
            best_load = load;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tie_break_rotates() {
        let counts: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        // All-zero counts: the pick must follow the rotation, not rank 0.
        assert_eq!(pick_least_loaded(&counts, 0), 0);
        assert_eq!(pick_least_loaded(&counts, 1), 1);
        assert_eq!(pick_least_loaded(&counts, 2), 2);
        assert_eq!(pick_least_loaded(&counts, 3), 0);
    }

    #[test]
    fn lower_load_beats_rotation() {
        let counts: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(5)).collect();
        counts[2].store(1, Ordering::Relaxed);
        for rotate in 0..6 {
            assert_eq!(pick_least_loaded(&counts, rotate), 2);
        }
    }

    #[test]
    fn equal_loads_tie_to_rotation_start() {
        let counts: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(7)).collect();
        assert_eq!(pick_least_loaded(&counts, 6), 2);
    }
}
