//! The serving front door: one TCP listener, N named per-model engine
//! pools, bounded admission with load shedding, per-request deadlines,
//! and graceful drain.
//!
//! ```text
//!            ┌────────────────────── IngestServer ──────────────────────┐
//!  client ──>│ accept ──> reader (per conn) ──> admission ──> pool queue│
//!            │              │  decode REQ_INFER     │             │     │
//!            │              │  route by model      full?──BUSY    ▼     │
//!            │              │  validate shapes                 batcher  │
//!            │              └── BUSY/ERROR ◄──────────────────> workers │
//!            │                                   OUTPUT/ERROR ◄──┘      │
//!            └──────────────────────────────────────────────────────────┘
//! ```
//!
//! **Admission** is token-based: a pool holds at most `queue_depth`
//! requests anywhere between admission and terminal response. A request
//! arriving at a full pool is answered [`ingest::RESP_BUSY`] immediately
//! (with a retry-after hint derived from the pool's smoothed batch time)
//! and never touches the queue — overload sheds at the door instead of
//! growing an unbounded backlog. Every admitted request is answered by
//! exactly one terminal frame, even through drain.
//!
//! **Deadlines** are measured from server-side arrival (`deadline_ms` on
//! the request; 0 = none). Workers re-check just before execution and
//! answer expired work with a typed error instead of spending an engine
//! slot on it.
//!
//! **Drain** ([`IngestServer::drain`]) stops the listener (new connects
//! are refused), closes the pool queues so workers finish everything
//! already admitted, and joins all pool threads before returning.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::dist::exec::wire::{read_frame, write_frame};
use crate::graph::{models, Shape};
use crate::hw::presets;
use crate::obs::metrics;
use crate::ops::params::ParamStore;
use crate::ops::Tensor;
use crate::quant::{CalibTable, Precision};
use crate::runtime::Engine;
use crate::serve::batcher::{Batcher, BatcherConfig};
use crate::serve::ingest::{self, ErrorCode};

/// Builds one worker's engine; called once per worker, in that worker's
/// thread, with the worker index (engines need not be `Send`).
pub type EngineFactory = Arc<dyn Fn(usize) -> Result<Engine> + Send + Sync>;

struct ModelEntry {
    factory: EngineFactory,
    shapes: Vec<Shape>,
    workers: usize,
    batcher: BatcherConfig,
}

/// Named per-model serving configurations sharing one listener. Requests
/// route by their model field; each model gets its own worker pool,
/// admission queue, and batching policy.
#[derive(Default)]
pub struct ModelRegistry {
    entries: BTreeMap<String, ModelEntry>,
}

impl ModelRegistry {
    /// Empty registry.
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Register a model under `name`: expected input shapes (requests are
    /// validated against them at admission), worker count, batching
    /// policy, and the per-worker engine factory.
    pub fn register(
        &mut self,
        name: &str,
        shapes: Vec<Shape>,
        workers: usize,
        batcher: BatcherConfig,
        factory: impl Fn(usize) -> Result<Engine> + Send + Sync + 'static,
    ) {
        assert!(workers >= 1, "workers must be >= 1");
        self.entries.insert(
            name.to_string(),
            ModelEntry { factory: Arc::new(factory), shapes, workers, batcher },
        );
    }

    /// Register a model-zoo graph under its zoo name (or an alias): F32
    /// runs the interpreter (parallel when `threads > 1`), INT8 runs the
    /// quantized engine calibrated on synthetic data — the same matrix
    /// `xenos serve` exposes for the in-process coordinator.
    pub fn register_zoo(
        &mut self,
        name: &str,
        zoo: &str,
        precision: Precision,
        threads: usize,
        workers: usize,
        batcher: BatcherConfig,
    ) -> Result<()> {
        let g = models::by_name(zoo).ok_or_else(|| anyhow!("unknown zoo model: {zoo}"))?;
        let shapes = Engine::interp(Arc::new(g.clone())).input_shapes();
        let graph = Arc::new(g);
        match precision {
            Precision::F32 => {
                let device = presets::tms320c6678();
                self.register(name, shapes, workers, batcher, move |_w| {
                    if threads > 1 {
                        Ok(Engine::par_interp(graph.clone(), &device, threads))
                    } else {
                        Ok(Engine::interp(graph.clone()))
                    }
                });
            }
            Precision::Int8 => {
                let calib =
                    CalibTable::synthetic(&graph, &ParamStore::for_graph(&graph), 8, 42);
                self.register(name, shapes, workers, batcher, move |_w| {
                    Engine::quant(graph.clone(), &calib, threads.max(1))
                });
            }
        }
        Ok(())
    }

    /// Register from a CLI spec: `name[=zoo][:precision]` — e.g.
    /// `mobilenet`, `mn=mobilenet:int8`. Omitted zoo defaults to the
    /// served name; omitted precision defaults to F32.
    pub fn register_spec(
        &mut self,
        spec: &str,
        threads: usize,
        workers: usize,
        batcher: BatcherConfig,
    ) -> Result<()> {
        let (head, precision) = match spec.rsplit_once(':') {
            Some((h, p)) => {
                (h, Precision::parse(p).ok_or_else(|| anyhow!("bad precision in {spec:?}"))?)
            }
            None => (spec, Precision::F32),
        };
        let (name, zoo) = match head.split_once('=') {
            Some((n, z)) => (n, z),
            None => (head, head),
        };
        if name.is_empty() || zoo.is_empty() {
            bail!("empty model name in spec {spec:?}");
        }
        self.register_zoo(name, zoo, precision, threads, workers, batcher)
    }

    /// Registered model names (sorted).
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Front-door tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct IngestConfig {
    /// Per-model admission bound: the most requests a pool holds anywhere
    /// between admission and terminal response. Arrivals beyond it shed.
    pub queue_depth: usize,
    /// Per-connection read deadline (à la `JobSpec::ctrl_deadline`): a
    /// connection that sends nothing for this long is closed so dead
    /// peers can't pin reader threads forever.
    pub read_timeout: Duration,
}

impl Default for IngestConfig {
    fn default() -> Self {
        IngestConfig { queue_depth: 64, read_timeout: Duration::from_secs(30) }
    }
}

/// Front-door accounting. The admission invariant:
/// `completed + shed + expired + engine_errors == submitted`.
#[derive(Debug, Clone, Copy, Default)]
pub struct IngestStats {
    /// Well-formed requests that reached admission (admitted or shed).
    pub submitted: u64,
    /// Requests answered with outputs.
    pub completed: u64,
    /// Requests answered [`ingest::RESP_BUSY`] at a full (or draining) pool.
    pub shed: u64,
    /// Admitted requests whose deadline passed before execution.
    pub expired: u64,
    /// Admitted requests whose engine batch failed.
    pub engine_errors: u64,
    /// Protocol-level rejections (unknown model, bad shapes) — answered
    /// with a typed error and a closed connection; never admitted.
    pub rejected: u64,
    /// Requests that actually entered an engine (`infer_batch`).
    pub executed: u64,
    /// Connections accepted over the server's lifetime.
    pub connections: u64,
}

#[derive(Default)]
struct StatsCells {
    submitted: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    expired: AtomicU64,
    engine_errors: AtomicU64,
    rejected: AtomicU64,
    executed: AtomicU64,
    connections: AtomicU64,
}

/// One admitted request: decoded inputs plus the reply socket, carried
/// through the pool's batcher to a worker.
struct IngestJob {
    id: u64,
    inputs: Vec<Tensor>,
    deadline: Option<Instant>,
    submitted: Instant,
    conn: ConnHandle,
}

/// Shared write half of a connection. Terminal frames lock it for the
/// whole `write_frame`, so replies from different threads never
/// interleave mid-frame.
type ConnHandle = Arc<Mutex<TcpStream>>;

struct PoolShared {
    name: String,
    shapes: Vec<Shape>,
    /// Admission gate: `None` once draining — no further sends possible.
    /// Senders are used only under this lock, so taking it is a barrier.
    tx: Mutex<Option<Sender<IngestJob>>>,
    /// Requests in the system (admission → terminal).
    depth: AtomicUsize,
    cap: usize,
    max_batch: usize,
    /// EWMA of one batch's engine seconds (f64 bits) — the retry-after
    /// hint's time base.
    ewma_batch_s: AtomicU64,
}

impl PoolShared {
    /// Try to take an admission slot; false means shed.
    fn acquire(&self) -> bool {
        self.depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
                (d < self.cap).then_some(d + 1)
            })
            .is_ok()
    }

    /// Release a slot at terminal response.
    fn release(&self) {
        let d = self.depth.fetch_sub(1, Ordering::Relaxed);
        metrics::gauge_set("serve.ingest.queue_depth", (d.saturating_sub(1)) as f64);
    }

    /// Milliseconds until a slot plausibly frees: the smoothed batch time
    /// times the number of batches queued ahead, clamped to [1, 1000].
    fn retry_after_ms(&self) -> u32 {
        let ewma = f64::from_bits(self.ewma_batch_s.load(Ordering::Relaxed)).max(0.001);
        let batches_ahead = (self.depth.load(Ordering::Relaxed) / self.max_batch + 1) as f64;
        (ewma * batches_ahead * 1e3).clamp(1.0, 1000.0) as u32
    }

    fn observe_batch_s(&self, s: f64) {
        let prev = f64::from_bits(self.ewma_batch_s.load(Ordering::Relaxed));
        let next = if prev == 0.0 { s } else { 0.8 * prev + 0.2 * s };
        self.ewma_batch_s.store(next.to_bits(), Ordering::Relaxed);
    }
}

struct ServerShared {
    draining: AtomicBool,
    stats: StatsCells,
    pools: BTreeMap<String, Arc<PoolShared>>,
    read_timeout: Duration,
}

/// The running front door. Dropping it drains.
pub struct IngestServer {
    addr: SocketAddr,
    shared: Arc<ServerShared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    drained: bool,
}

impl IngestServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port), build
    /// every pool's engines, and start accepting. Fails — with all
    /// already-started threads cleanly joined — if binding fails or any
    /// engine factory errors.
    pub fn start(addr: &str, registry: ModelRegistry, cfg: IngestConfig) -> Result<IngestServer> {
        assert!(cfg.queue_depth >= 1, "queue_depth must be >= 1");
        if registry.is_empty() {
            bail!("refusing to serve an empty model registry");
        }
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr().context("local_addr")?;

        let mut pools = BTreeMap::new();
        let mut workers: Vec<JoinHandle<()>> = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let mut expected_ready = 0usize;

        struct PoolBuild {
            shared: Arc<PoolShared>,
            rx: Arc<Mutex<Receiver<IngestJob>>>,
            entry: ModelEntry,
        }
        let mut builds: Vec<PoolBuild> = Vec::new();
        for (name, entry) in registry.entries {
            let (tx, rx) = mpsc::channel::<IngestJob>();
            let shared = Arc::new(PoolShared {
                name: name.clone(),
                shapes: entry.shapes.clone(),
                tx: Mutex::new(Some(tx)),
                depth: AtomicUsize::new(0),
                cap: cfg.queue_depth,
                max_batch: entry.batcher.max_batch,
                ewma_batch_s: AtomicU64::new(0),
            });
            pools.insert(name, shared.clone());
            builds.push(PoolBuild { shared, rx: Arc::new(Mutex::new(rx)), entry });
        }

        let shared = Arc::new(ServerShared {
            draining: AtomicBool::new(false),
            stats: StatsCells::default(),
            pools,
            read_timeout: cfg.read_timeout,
        });

        for build in builds {
            for w in 0..build.entry.workers {
                expected_ready += 1;
                let pool = build.shared.clone();
                let rx = build.rx.clone();
                let factory = build.entry.factory.clone();
                let batcher_cfg = build.entry.batcher;
                let srv = shared.clone();
                let ready = ready_tx.clone();
                workers.push(std::thread::spawn(move || {
                    let engine = match factory(w) {
                        Ok(e) => {
                            let _ = ready.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = ready
                                .send(Err(format!("pool {}: worker {w}: {e:#}", pool.name)));
                            return;
                        }
                    };
                    run_worker(&pool, &rx, &batcher_cfg, &engine, &srv);
                }));
            }
        }
        drop(ready_tx);

        let mut failures = Vec::new();
        for _ in 0..expected_ready {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => failures.push(msg),
                Err(_) => failures.push("worker died before reporting readiness".into()),
            }
        }
        if !failures.is_empty() {
            // Close the queues so healthy workers exit, then join.
            for pool in shared.pools.values() {
                pool.tx.lock().unwrap_or_else(|p| p.into_inner()).take();
            }
            for h in workers {
                let _ = h.join();
            }
            bail!("engine startup failed: {}", failures.join("; "));
        }

        let accept_shared = shared.clone();
        let accept = std::thread::spawn(move || {
            // The listener lives (and dies) with this thread: once drain
            // joins it, the port is closed and new connects are refused.
            for conn in listener.incoming() {
                if accept_shared.draining.load(Ordering::Acquire) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        accept_shared.stats.connections.fetch_add(1, Ordering::Relaxed);
                        let srv = accept_shared.clone();
                        std::thread::spawn(move || run_connection(stream, &srv));
                    }
                    Err(e) => {
                        crate::xwarn!("ingest accept failed: {e}");
                    }
                }
            }
        });

        crate::xinfo!(
            "ingest: serving {} model(s) on {local} (queue depth {})",
            shared.pools.len(),
            cfg.queue_depth
        );
        Ok(IngestServer { addr: local, shared, accept: Some(accept), workers, drained: false })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot the accounting counters.
    pub fn stats(&self) -> IngestStats {
        let s = &self.shared.stats;
        IngestStats {
            submitted: s.submitted.load(Ordering::Relaxed),
            completed: s.completed.load(Ordering::Relaxed),
            shed: s.shed.load(Ordering::Relaxed),
            expired: s.expired.load(Ordering::Relaxed),
            engine_errors: s.engine_errors.load(Ordering::Relaxed),
            rejected: s.rejected.load(Ordering::Relaxed),
            executed: s.executed.load(Ordering::Relaxed),
            connections: s.connections.load(Ordering::Relaxed),
        }
    }

    /// Graceful shutdown: refuse new connections, answer everything
    /// already admitted (outputs, expiry, or engine error — never
    /// silence), join every pool thread, and return the final stats.
    pub fn drain(&mut self) -> IngestStats {
        if !self.drained {
            self.drained = true;
            self.shared.draining.store(true, Ordering::Release);
            // Wake the blocking accept so it observes the flag; the
            // connection itself is dropped unserved.
            let _ = TcpStream::connect(self.addr);
            if let Some(h) = self.accept.take() {
                let _ = h.join();
            }
            // Closing the queues lets workers drain what's left and exit.
            for pool in self.shared.pools.values() {
                pool.tx.lock().unwrap_or_else(|p| p.into_inner()).take();
            }
            for h in self.workers.drain(..) {
                let _ = h.join();
            }
        }
        self.stats()
    }
}

impl Drop for IngestServer {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Send one terminal frame on a connection; write failures are logged
/// and swallowed (the client is gone — accounting already happened).
fn send_terminal(conn: &ConnHandle, tag: u64, payload: &[u8]) {
    let mut stream = conn.lock().unwrap_or_else(|p| p.into_inner());
    if let Err(e) = write_frame(&mut *stream, tag, payload) {
        crate::xdebug!("ingest: reply write failed: {e}");
    }
}

/// Per-connection reader: decode pipelined requests, route, admit or
/// shed. Returns (closing the connection) on read errors, unknown tags,
/// undecodable payloads, unknown models, or shape mismatches — protocol
/// errors kill only the offending connection.
fn run_connection(stream: TcpStream, srv: &Arc<ServerShared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(srv.read_timeout));
    let mut read_half = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            crate::xwarn!("ingest: clone failed: {e}");
            return;
        }
    };
    let conn: ConnHandle = Arc::new(Mutex::new(stream));

    loop {
        let (tag, payload) = match read_frame(&mut read_half) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return,
            Err(e) => {
                crate::xdebug!("ingest: read failed, closing connection: {e}");
                return;
            }
        };
        if tag != ingest::REQ_INFER {
            crate::xwarn!("ingest: unknown tag {tag:#x}, closing connection");
            return;
        }
        let req = match ingest::decode_request(&payload) {
            Ok(r) => r,
            Err(e) => {
                srv.stats.rejected.fetch_add(1, Ordering::Relaxed);
                send_terminal(
                    &conn,
                    ingest::RESP_ERROR,
                    &ingest::encode_error(0, ErrorCode::BadRequest, &format!("{e:#}")),
                );
                return;
            }
        };
        let arrival = Instant::now();

        let Some(pool) = srv.pools.get(&req.model) else {
            srv.stats.rejected.fetch_add(1, Ordering::Relaxed);
            send_terminal(
                &conn,
                ingest::RESP_ERROR,
                &ingest::encode_error(
                    req.id,
                    ErrorCode::UnknownModel,
                    &format!("no such model: {}", req.model),
                ),
            );
            return;
        };
        let got: Vec<&Shape> = req.inputs.iter().map(|t| t.shape()).collect();
        if got.len() != pool.shapes.len() || got.iter().zip(&pool.shapes).any(|(a, b)| **a != *b)
        {
            srv.stats.rejected.fetch_add(1, Ordering::Relaxed);
            send_terminal(
                &conn,
                ingest::RESP_ERROR,
                &ingest::encode_error(
                    req.id,
                    ErrorCode::BadRequest,
                    &format!(
                        "input shapes {:?} do not match model {} ({:?})",
                        got, pool.name, pool.shapes
                    ),
                ),
            );
            return;
        }

        // Well-formed and routed: from here the request is `submitted`
        // and gets exactly one terminal — admit or shed.
        let req_id = req.id;
        srv.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let shed = |pool: &PoolShared| {
            srv.stats.shed.fetch_add(1, Ordering::Relaxed);
            metrics::counter_add("serve.ingest.shed", 1);
            send_terminal(
                &conn,
                ingest::RESP_BUSY,
                &ingest::encode_busy(req_id, pool.retry_after_ms()),
            );
        };
        if srv.draining.load(Ordering::Acquire) || !pool.acquire() {
            shed(pool.as_ref());
            continue;
        }
        metrics::counter_add("serve.ingest.accepted", 1);
        metrics::gauge_set(
            "serve.ingest.queue_depth",
            pool.depth.load(Ordering::Relaxed) as f64,
        );
        let deadline = (req.deadline_ms > 0)
            .then(|| arrival + Duration::from_millis(req.deadline_ms as u64));
        let job = IngestJob {
            id: req.id,
            inputs: req.inputs,
            deadline,
            submitted: arrival,
            conn: conn.clone(),
        };
        // Send under the gate lock: after drain takes the sender, nothing
        // can enqueue, so workers never miss an admitted job.
        let sent = {
            let gate = pool.tx.lock().unwrap_or_else(|p| p.into_inner());
            match gate.as_ref() {
                Some(tx) => tx.send(job).is_ok(),
                None => false,
            }
        };
        if !sent {
            // Raced the drain: give the slot back and shed instead.
            pool.release();
            shed(pool.as_ref());
        }
    }
}

/// Pool worker: batch admitted jobs off the shared queue, drop expired
/// ones with a typed error, run the rest as one engine batch, and answer
/// every job with exactly one terminal frame.
fn run_worker(
    pool: &PoolShared,
    rx: &Arc<Mutex<Receiver<IngestJob>>>,
    batcher_cfg: &BatcherConfig,
    engine: &Engine,
    srv: &Arc<ServerShared>,
) {
    let batcher = Batcher::new(*batcher_cfg);
    loop {
        // Hold the queue lock only while forming the batch; inference
        // runs unlocked so other workers batch concurrently.
        let batch = {
            let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
            match batcher.next_batch(&guard) {
                Some(b) => b,
                None => return,
            }
        };
        let now = Instant::now();
        let mut live: Vec<IngestJob> = Vec::with_capacity(batch.requests.len());
        for job in batch.requests {
            if job.deadline.is_some_and(|d| now >= d) {
                srv.stats.expired.fetch_add(1, Ordering::Relaxed);
                metrics::counter_add("serve.ingest.expired", 1);
                send_terminal(
                    &job.conn,
                    ingest::RESP_ERROR,
                    &ingest::encode_error(
                        job.id,
                        ErrorCode::Expired,
                        "deadline passed before execution",
                    ),
                );
                pool.release();
            } else {
                live.push(job);
            }
        }
        if live.is_empty() {
            continue;
        }
        srv.stats.executed.fetch_add(live.len() as u64, Ordering::Relaxed);
        let inputs: Vec<Vec<Tensor>> =
            live.iter_mut().map(|j| std::mem::take(&mut j.inputs)).collect();
        match engine.infer_batch(&inputs) {
            Ok(out) => {
                pool.observe_batch_s(out.exec_s);
                let bs = live.len() as u32;
                for (job, outs) in live.iter().zip(out.outputs) {
                    let latency = job.submitted.elapsed().as_secs_f64();
                    metrics::observe("serve.ingest.latency_s", latency);
                    send_terminal(
                        &job.conn,
                        ingest::RESP_OUTPUT,
                        &ingest::encode_output(job.id, bs, &outs),
                    );
                    srv.stats.completed.fetch_add(1, Ordering::Relaxed);
                    pool.release();
                }
            }
            Err(e) => {
                crate::xerror!("ingest: pool {}: batch failed: {e:#}", pool.name);
                for job in &live {
                    send_terminal(
                        &job.conn,
                        ingest::RESP_ERROR,
                        &ingest::encode_error(job.id, ErrorCode::Engine, &format!("{e:#}")),
                    );
                    srv.stats.engine_errors.fetch_add(1, Ordering::Relaxed);
                    pool.release();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_specs_parse() {
        let mut r = ModelRegistry::new();
        r.register_spec("mobilenet", 1, 1, BatcherConfig::default()).unwrap();
        r.register_spec("mn8=mobilenet:int8", 1, 1, BatcherConfig::default()).unwrap();
        r.register_spec("sq=squeezenet", 1, 1, BatcherConfig::default()).unwrap();
        assert_eq!(r.names(), vec!["mn8".to_string(), "mobilenet".into(), "sq".into()]);
        assert_eq!(r.len(), 3);
        assert!(!r.is_empty());
    }

    #[test]
    fn bad_specs_rejected() {
        let mut r = ModelRegistry::new();
        assert!(r.register_spec("nope", 1, 1, BatcherConfig::default()).is_err());
        assert!(r.register_spec("x=mobilenet:float64", 1, 1, BatcherConfig::default()).is_err());
        assert!(r.register_spec("=mobilenet", 1, 1, BatcherConfig::default()).is_err());
        assert!(r.is_empty());
    }

    #[test]
    fn retry_hint_scales_with_depth() {
        let pool = PoolShared {
            name: "t".into(),
            shapes: Vec::new(),
            tx: Mutex::new(None),
            depth: AtomicUsize::new(0),
            cap: 4,
            max_batch: 2,
            ewma_batch_s: AtomicU64::new(0.010f64.to_bits()),
        };
        let idle = pool.retry_after_ms();
        pool.depth.store(4, Ordering::Relaxed);
        let loaded = pool.retry_after_ms();
        assert!(idle >= 1);
        assert!(loaded > idle, "hint must grow with backlog: {idle} vs {loaded}");
        assert!(loaded <= 1000);
    }

    #[test]
    fn empty_registry_refused() {
        let err = IngestServer::start("127.0.0.1:0", ModelRegistry::new(), IngestConfig::default())
            .unwrap_err();
        assert!(err.to_string().contains("empty model registry"));
    }

    #[test]
    fn failing_factory_fails_start() {
        let mut r = ModelRegistry::new();
        r.register(
            "broken",
            Vec::new(),
            2,
            BatcherConfig::default(),
            |_w| anyhow::bail!("no such artifact"),
        );
        let err =
            IngestServer::start("127.0.0.1:0", r, IngestConfig::default()).unwrap_err();
        assert!(err.to_string().contains("engine startup failed"), "{err}");
    }
}
