//! Dynamic batching: group queued requests up to a size cap or a deadline,
//! whichever comes first — the "batch transmission mechanism" of the
//! paper's communication middleware (§6.2), applied to inference requests.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::Request;

/// Batcher policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Maximum requests per batch (≥1).
    pub max_batch: usize,
    /// Maximum time to hold an open batch.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// A formed batch: the requests plus the assembly-window timestamps, so
/// the serving report can split queue wait from batch assembly per
/// request. Generic over the queued item — the in-process coordinator
/// batches [`Request`]s, the network front door batches its own job type
/// carrying the reply socket and deadline.
#[derive(Debug)]
pub struct Batch<T = Request> {
    /// Requests in arrival order.
    pub requests: Vec<T>,
    /// When the first request was pulled (the batch opened).
    pub opened: Instant,
    /// When the batch was closed (size cap or deadline reached).
    pub formed: Instant,
}

impl<T> Batch<T> {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True if the batch holds no requests (the batcher never produces
    /// one, but slicing code may).
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Pull-based dynamic batcher over an mpsc channel.
#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherConfig,
}

impl Batcher {
    /// Create a batcher with the given policy.
    pub fn new(cfg: BatcherConfig) -> Batcher {
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        Batcher { cfg }
    }

    /// Form the next batch. Blocks for the first request, then fills until
    /// `max_batch` or `max_wait`. Returns `None` once the channel is closed
    /// and drained.
    pub fn next_batch<T>(&self, rx: &Receiver<T>) -> Option<Batch<T>> {
        let first = rx.recv().ok()?;
        let opened = Instant::now();
        let deadline = opened + self.cfg.max_wait;
        let mut requests = vec![first];
        while requests.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => requests.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(Batch { requests, opened, formed: Instant::now() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::Tensor;
    use std::sync::mpsc;

    fn req(id: u64) -> Request {
        Request { id, inputs: vec![Tensor::mat(1, 1, vec![0.0])], submitted: Instant::now() }
    }

    #[test]
    fn fills_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let b = Batcher::new(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(50) });
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.requests[0].id, 0);
        assert_eq!(batch.requests[3].id, 3);
        assert!(batch.opened <= batch.formed);
        assert!(!batch.is_empty());
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        let b = Batcher::new(BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn closed_empty_channel_yields_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let b = Batcher::new(BatcherConfig::default());
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn drains_after_close() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(1)).unwrap();
        tx.send(req(2)).unwrap();
        drop(tx);
        let b = Batcher::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) });
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    #[should_panic(expected = "max_batch")]
    fn zero_batch_rejected() {
        Batcher::new(BatcherConfig { max_batch: 0, max_wait: Duration::from_millis(1) });
    }
}
