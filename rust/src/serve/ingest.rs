//! Wire format of the serving front door.
//!
//! Clients talk to [`crate::serve::server::IngestServer`] over the same
//! `[tag u64][len u32][payload]` little-endian framing the cluster
//! protocol uses ([`crate::dist::exec::wire`]), under four new tags in a
//! range disjoint from the `CTRL_*` block. A connection carries any
//! number of pipelined requests; every request is answered by **exactly
//! one** terminal frame — output, error, or busy — matched by the echoed
//! request id. Frames never interleave mid-frame, so one reader thread
//! per connection suffices on both sides.
//!
//! All decoders return typed errors on malformed input — never panic,
//! never allocate more than the payload could actually deliver — because
//! this layer fronts untrusted sockets.

use anyhow::{bail, Result};

use crate::dist::exec::wire::{self, Dec, Enc};
use crate::ops::Tensor;

/// Client → server: one inference request ([`encode_request`]).
pub const REQ_INFER: u64 = 0xFFFF_0101;
/// Server → client: the request's outputs ([`encode_output`]).
pub const RESP_OUTPUT: u64 = 0xFFFF_0102;
/// Server → client: the request failed ([`encode_error`]); the code says
/// whether the connection survives (engine/expiry errors do, protocol
/// errors kill it).
pub const RESP_ERROR: u64 = 0xFFFF_0103;
/// Server → client: load-shed — the admission queue was full; payload
/// carries a retry-after hint ([`encode_busy`]).
pub const RESP_BUSY: u64 = 0xFFFF_0104;

/// Why a request got a [`RESP_ERROR`] terminal instead of outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// The request's deadline passed before an engine picked it up; the
    /// work was dropped without spending an engine slot.
    Expired,
    /// The request named a model the registry doesn't host.
    UnknownModel,
    /// The engine itself failed while executing the batch.
    Engine,
    /// The request was malformed (undecodable payload, wrong input
    /// shapes); the server closes the connection after answering.
    BadRequest,
}

impl ErrorCode {
    /// Wire representation.
    pub fn code(self) -> u32 {
        match self {
            ErrorCode::Expired => 1,
            ErrorCode::UnknownModel => 2,
            ErrorCode::Engine => 3,
            ErrorCode::BadRequest => 4,
        }
    }

    /// Parse the wire representation.
    pub fn from_code(v: u32) -> Option<ErrorCode> {
        match v {
            1 => Some(ErrorCode::Expired),
            2 => Some(ErrorCode::UnknownModel),
            3 => Some(ErrorCode::Engine),
            4 => Some(ErrorCode::BadRequest),
            _ => None,
        }
    }

    /// Human-readable label (stats lines, log messages).
    pub fn label(self) -> &'static str {
        match self {
            ErrorCode::Expired => "expired",
            ErrorCode::UnknownModel => "unknown-model",
            ErrorCode::Engine => "engine",
            ErrorCode::BadRequest => "bad-request",
        }
    }
}

/// One decoded [`REQ_INFER`] payload.
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    /// Caller-assigned id, echoed on the terminal frame. Uniqueness is
    /// the caller's problem; the server never inspects it beyond echoing.
    pub id: u64,
    /// Registry name of the model to run.
    pub model: String,
    /// Milliseconds the caller is willing to wait before the server may
    /// drop the request unexecuted (`0` = no deadline). Measured from
    /// server-side arrival, so clock skew never expires work in flight.
    pub deadline_ms: u32,
    /// Model inputs, one tensor per graph input.
    pub inputs: Vec<Tensor>,
}

/// Encode a [`REQ_INFER`] payload.
pub fn encode_request(req: &InferRequest) -> Vec<u8> {
    let mut e = Enc { buf: Vec::new() };
    e.u64(req.id);
    e.str(&req.model);
    e.u32(req.deadline_ms);
    e.buf.extend_from_slice(&wire::encode_tensors(&req.inputs));
    e.buf
}

/// Decode a [`REQ_INFER`] payload.
pub fn decode_request(payload: &[u8]) -> Result<InferRequest> {
    let mut d = Dec::new(payload);
    let id = d.u64()?;
    let model = d.str()?;
    let deadline_ms = d.u32()?;
    let inputs = wire::decode_tensors(d.rest())?;
    Ok(InferRequest { id, model, deadline_ms, inputs })
}

/// Encode a [`RESP_OUTPUT`] payload: the echoed id, the batch size the
/// request was served in (observability; amortized-cost math), and the
/// output tensors.
pub fn encode_output(id: u64, batch_size: u32, outputs: &[Tensor]) -> Vec<u8> {
    let mut e = Enc { buf: Vec::new() };
    e.u64(id);
    e.u32(batch_size);
    e.buf.extend_from_slice(&wire::encode_tensors(outputs));
    e.buf
}

/// Decode a [`RESP_OUTPUT`] payload → `(id, batch_size, outputs)`.
pub fn decode_output(payload: &[u8]) -> Result<(u64, u32, Vec<Tensor>)> {
    let mut d = Dec::new(payload);
    let id = d.u64()?;
    let batch_size = d.u32()?;
    let outputs = wire::decode_tensors(d.rest())?;
    Ok((id, batch_size, outputs))
}

/// Encode a [`RESP_ERROR`] payload.
pub fn encode_error(id: u64, code: ErrorCode, message: &str) -> Vec<u8> {
    let mut e = Enc { buf: Vec::new() };
    e.u64(id);
    e.u32(code.code());
    e.str(message);
    e.buf
}

/// Decode a [`RESP_ERROR`] payload → `(id, code, message)`.
pub fn decode_error(payload: &[u8]) -> Result<(u64, ErrorCode, String)> {
    let mut d = Dec::new(payload);
    let id = d.u64()?;
    let raw = d.u32()?;
    let Some(code) = ErrorCode::from_code(raw) else {
        bail!("unknown ingest error code {raw}");
    };
    let message = d.str()?;
    Ok((id, code, message))
}

/// Encode a [`RESP_BUSY`] payload: the echoed id and a retry-after hint
/// in milliseconds (the server's estimate of when a slot frees up).
pub fn encode_busy(id: u64, retry_after_ms: u32) -> Vec<u8> {
    let mut e = Enc { buf: Vec::new() };
    e.u64(id);
    e.u32(retry_after_ms);
    e.buf
}

/// Decode a [`RESP_BUSY`] payload → `(id, retry_after_ms)`.
pub fn decode_busy(payload: &[u8]) -> Result<(u64, u32)> {
    let mut d = Dec::new(payload);
    let id = d.u64()?;
    let retry_after_ms = d.u32()?;
    Ok((id, retry_after_ms))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Shape, TensorDesc};

    fn sample_request() -> InferRequest {
        InferRequest {
            id: 7,
            model: "mobilenet".into(),
            deadline_ms: 250,
            inputs: vec![
                Tensor::fm(1, 2, 2, 2, (0..8).map(|v| v as f32).collect()),
                Tensor::new(TensorDesc::plain(Shape::new(vec![3])), vec![1.0, -2.0, 0.5]),
            ],
        }
    }

    #[test]
    fn request_round_trips() {
        let req = sample_request();
        let back = decode_request(&encode_request(&req)).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn output_round_trips() {
        let outs = vec![Tensor::mat(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])];
        let (id, bs, back) = decode_output(&encode_output(42, 8, &outs)).unwrap();
        assert_eq!(id, 42);
        assert_eq!(bs, 8);
        assert_eq!(back, outs);
    }

    #[test]
    fn error_round_trips() {
        let payload = encode_error(9, ErrorCode::UnknownModel, "no such model: zeta");
        let (id, code, msg) = decode_error(&payload).unwrap();
        assert_eq!(id, 9);
        assert_eq!(code, ErrorCode::UnknownModel);
        assert_eq!(msg, "no such model: zeta");
    }

    #[test]
    fn busy_round_trips() {
        let (id, retry) = decode_busy(&encode_busy(3, 17)).unwrap();
        assert_eq!(id, 3);
        assert_eq!(retry, 17);
    }

    #[test]
    fn truncated_request_is_typed_error() {
        let full = encode_request(&sample_request());
        for cut in [0, 4, 9, full.len() - 1] {
            let err = decode_request(&full[..cut]).unwrap_err();
            assert!(err.to_string().contains("truncated"), "cut={cut}: {err}");
        }
    }

    #[test]
    fn unknown_error_code_rejected() {
        let mut e = Enc { buf: Vec::new() };
        e.u64(1);
        e.u32(99);
        e.str("?");
        assert!(decode_error(&e.buf).is_err());
    }

    #[test]
    fn error_codes_round_trip() {
        for code in [
            ErrorCode::Expired,
            ErrorCode::UnknownModel,
            ErrorCode::Engine,
            ErrorCode::BadRequest,
        ] {
            assert_eq!(ErrorCode::from_code(code.code()), Some(code));
        }
        assert_eq!(ErrorCode::from_code(0), None);
        assert_eq!(ErrorCode::from_code(5), None);
    }
}
