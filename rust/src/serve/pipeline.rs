//! The paper's §2.1 three-stage inference workflow: image acquisition →
//! preprocessing → inference, with per-stage timing.
//!
//! The acquisition stage synthesizes camera frames (the paper's high-speed
//! image collector over SRIO is hardware we substitute, DESIGN.md
//! §Substitutions); preprocessing does the resize + normalization the paper
//! describes; inference goes through an [`Engine`]. The report verifies the
//! paper's motivating observation: the inference module dominates
//! (">60% of the overall execution time").

use anyhow::Result;

use crate::graph::Shape;
use crate::ops::Tensor;
use crate::runtime::Engine;
use crate::util::rng::Rng;
use std::time::Instant;

/// Pipeline configuration.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Frames to process.
    pub frames: usize,
    /// Source frame height/width (acquisition emits square RGB frames).
    pub src_hw: usize,
    /// RNG seed for frame synthesis.
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { frames: 16, src_hw: 32, seed: 7 }
    }
}

/// Per-stage timing report.
#[derive(Debug)]
pub struct PipelineReport {
    /// Frames processed.
    pub frames: usize,
    /// Acquisition time, seconds (total).
    pub acquire_s: f64,
    /// Preprocess time, seconds.
    pub preprocess_s: f64,
    /// Inference time, seconds.
    pub inference_s: f64,
    /// Final outputs of the last frame.
    pub last_output: Vec<Tensor>,
}

impl PipelineReport {
    /// Fraction of total pipeline time spent in the inference module.
    pub fn inference_share(&self) -> f64 {
        let total = self.acquire_s + self.preprocess_s + self.inference_s;
        if total <= 0.0 {
            0.0
        } else {
            self.inference_s / total
        }
    }
}

/// Synthesize one camera frame: HWC u8-ish values in [0, 255].
fn acquire_frame(rng: &mut Rng, hw: usize) -> Vec<f32> {
    (0..hw * hw * 3).map(|_| (rng.next_u64() % 256) as f32).collect()
}

/// Preprocess: bilinear-ish resize (nearest for determinism) from
/// `src_hw`² RGB to the engine's input shape, then normalize to [-1, 1],
/// replicating channels if the model wants more than 3.
fn preprocess(frame: &[f32], src_hw: usize, want: &Shape) -> Tensor {
    let dims = &want.dims;
    // Accept NHWC or NCHW-ish 4-D shapes; infer H/W/C heuristically.
    assert_eq!(dims.len(), 4, "pipeline expects 4-D model input");
    let (h, w, c) = (dims[1], dims[2], dims[3]); // our artifacts are NHWC
    let mut out = vec![0.0f32; want.numel()];
    for y in 0..h {
        for x in 0..w {
            let sy = y * src_hw / h;
            let sx = x * src_hw / w;
            for ch in 0..c {
                let src_c = ch % 3;
                let v = frame[(sy * src_hw + sx) * 3 + src_c];
                out[(y * w + x) * c + ch] = v / 127.5 - 1.0;
            }
        }
    }
    Tensor::new(crate::graph::TensorDesc::plain(want.clone()), out)
}

/// Run the full pipeline.
pub fn run_pipeline(engine: &Engine, cfg: PipelineConfig) -> Result<PipelineReport> {
    let mut rng = Rng::new(cfg.seed);
    let want = engine
        .input_shapes()
        .first()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("engine has no inputs"))?;

    let (mut t_acq, mut t_pre, mut t_inf) = (0.0, 0.0, 0.0);
    let mut last_output = Vec::new();
    for _ in 0..cfg.frames {
        let t0 = Instant::now();
        let frame = acquire_frame(&mut rng, cfg.src_hw);
        let t1 = Instant::now();
        let input = preprocess(&frame, cfg.src_hw, &want);
        let t2 = Instant::now();
        let out = engine.infer(&[input])?;
        let t3 = Instant::now();
        t_acq += (t1 - t0).as_secs_f64();
        t_pre += (t2 - t1).as_secs_f64();
        t_inf += (t3 - t2).as_secs_f64();
        last_output = out.outputs;
    }
    Ok(PipelineReport {
        frames: cfg.frames,
        acquire_s: t_acq,
        preprocess_s: t_pre,
        inference_s: t_inf,
        last_output,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use std::sync::Arc;

    fn engine() -> Engine {
        let mut b = GraphBuilder::new("pipe_test");
        let x = b.input("x", Shape::new(vec![1, 8, 8, 3]));
        let s = b.sigmoid("s", x);
        b.output(s);
        Engine::interp(Arc::new(b.finish()))
    }

    #[test]
    fn pipeline_processes_all_frames() {
        let r = run_pipeline(&engine(), PipelineConfig { frames: 4, src_hw: 16, seed: 1 })
            .unwrap();
        assert_eq!(r.frames, 4);
        assert!(!r.last_output.is_empty());
        assert!(r.inference_s > 0.0);
    }

    #[test]
    fn preprocess_normalizes_to_unit_range() {
        let mut rng = Rng::new(2);
        let frame = acquire_frame(&mut rng, 16);
        let t = preprocess(&frame, 16, &Shape::new(vec![1, 8, 8, 3]));
        assert!(t.data.iter().all(|v| (-1.0..=1.0).contains(v)));
    }

    #[test]
    fn preprocess_replicates_channels() {
        let frame = vec![255.0; 4 * 4 * 3];
        let t = preprocess(&frame, 4, &Shape::new(vec![1, 2, 2, 6]));
        assert!(t.data.iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }

    #[test]
    fn inference_share_is_fraction() {
        let r = run_pipeline(&engine(), PipelineConfig::default()).unwrap();
        let share = r.inference_share();
        assert!((0.0..=1.0).contains(&share));
    }
}
