//! Frame-level client for the serving front door, plus a multi-lane load
//! driver — the test suite, the ingest bench, and the `xenos client` verb
//! all speak through this module so the protocol lives in one place.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::dist::exec::wire::{read_frame, write_frame};
use crate::graph::Shape;
use crate::ops::Tensor;
use crate::serve::ingest::{self, ErrorCode, InferRequest};
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// The one terminal frame every request is answered with.
#[derive(Debug, Clone, PartialEq)]
pub enum Terminal {
    /// The request ran; outputs plus the batch size it was served in.
    Output {
        /// Echoed request id.
        id: u64,
        /// Batch size the request executed in.
        batch_size: u32,
        /// Model outputs.
        outputs: Vec<Tensor>,
    },
    /// The request was shed at a full admission queue.
    Busy {
        /// Echoed request id.
        id: u64,
        /// Server's estimate of when a slot frees, milliseconds.
        retry_after_ms: u32,
    },
    /// The request failed with a typed error.
    Error {
        /// Echoed request id (0 when the request was undecodable).
        id: u64,
        /// Failure class.
        code: ErrorCode,
        /// Human-readable detail.
        message: String,
    },
}

impl Terminal {
    /// The echoed request id.
    pub fn id(&self) -> u64 {
        match self {
            Terminal::Output { id, .. } | Terminal::Busy { id, .. } | Terminal::Error { id, .. } => {
                *id
            }
        }
    }
}

/// One connection to an [`crate::serve::server::IngestServer`]. Requests
/// may be pipelined: [`send`](IngestClient::send) any number, then
/// [`recv`](IngestClient::recv) the terminals (the server answers sheds
/// immediately and outputs as batches complete, so terminal order is not
/// submission order — match on [`Terminal::id`]).
pub struct IngestClient {
    stream: TcpStream,
}

impl IngestClient {
    /// Connect; `read_timeout` bounds how long [`recv`](IngestClient::recv)
    /// blocks (`None` = forever).
    pub fn connect(addr: &str, read_timeout: Option<Duration>) -> Result<IngestClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).context("set_nodelay")?;
        stream.set_read_timeout(read_timeout).context("set_read_timeout")?;
        Ok(IngestClient { stream })
    }

    /// Send one request frame.
    pub fn send(&mut self, req: &InferRequest) -> Result<()> {
        write_frame(&mut self.stream, ingest::REQ_INFER, &ingest::encode_request(req))
            .context("send request")?;
        Ok(())
    }

    /// Receive the next terminal frame.
    pub fn recv(&mut self) -> Result<Terminal> {
        let (tag, payload) = read_frame(&mut self.stream).context("read terminal")?;
        match tag {
            ingest::RESP_OUTPUT => {
                let (id, batch_size, outputs) = ingest::decode_output(&payload)?;
                Ok(Terminal::Output { id, batch_size, outputs })
            }
            ingest::RESP_BUSY => {
                let (id, retry_after_ms) = ingest::decode_busy(&payload)?;
                Ok(Terminal::Busy { id, retry_after_ms })
            }
            ingest::RESP_ERROR => {
                let (id, code, message) = ingest::decode_error(&payload)?;
                Ok(Terminal::Error { id, code, message })
            }
            other => bail!("unexpected terminal tag {other:#x}"),
        }
    }

    /// Send one request and block for its terminal.
    pub fn infer(&mut self, req: &InferRequest) -> Result<Terminal> {
        self.send(req)?;
        self.recv()
    }
}

/// What a [`drive_load`] run saw, lane totals merged.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Requests sent.
    pub submitted: u64,
    /// Output terminals.
    pub completed: u64,
    /// Busy terminals.
    pub shed: u64,
    /// Expired-error terminals.
    pub expired: u64,
    /// Other error terminals (engine, protocol).
    pub errors: u64,
    /// Latency of completed requests (send → output), seconds.
    pub latency: Option<Summary>,
    /// Wall-clock of the whole run, seconds.
    pub wall_s: f64,
}

/// Seeded synthetic inputs for request `id` — byte-for-byte reproducible,
/// so differential tests can regenerate exactly what a lane sent. Descs
/// follow the wire's reconstruction rule (rank-4 shapes become NCHW
/// feature maps): a request built here decodes server-side to tensors
/// identical to these, so served outputs compare bit-exact against a
/// direct `Engine::infer` on the same values.
pub fn synthetic_request_inputs(shapes: &[Shape], seed: u64, id: u64) -> Vec<Tensor> {
    use crate::graph::TensorDesc;
    let mut rng = Rng::new(seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    shapes
        .iter()
        .map(|s| {
            let desc = if s.is_fm() {
                TensorDesc::fm(s.dims[0], s.dims[1], s.dims[2], s.dims[3])
            } else {
                TensorDesc::plain(s.clone())
            };
            let data = rng.vec_uniform(s.numel());
            Tensor::new(desc, data)
        })
        .collect()
}

/// Closed-loop load driver: `lanes` connections, one request in flight
/// per lane, `n` requests total (lane `l` sends ids `l, l+lanes, …`).
/// Every terminal is tallied; a lane that loses its connection reports
/// the remainder of its ids as errors rather than under-counting.
#[allow(clippy::too_many_arguments)]
pub fn drive_load(
    addr: &str,
    model: &str,
    shapes: &[Shape],
    n: usize,
    lanes: usize,
    deadline_ms: u32,
    read_timeout: Duration,
    seed: u64,
) -> Result<LoadReport> {
    assert!(lanes >= 1, "lanes must be >= 1");

    #[derive(Default)]
    struct LaneTally {
        completed: u64,
        shed: u64,
        expired: u64,
        errors: u64,
        latencies: Vec<f64>,
    }

    let start = Instant::now();
    let mut tallies: Vec<Result<LaneTally>> = Vec::with_capacity(lanes);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(lanes);
        for lane in 0..lanes {
            handles.push(scope.spawn(move || -> Result<LaneTally> {
                let mut client = IngestClient::connect(addr, Some(read_timeout))?;
                let mut t = LaneTally::default();
                let mut id = lane as u64;
                while (id as usize) < n {
                    let inputs = synthetic_request_inputs(shapes, seed, id);
                    let req =
                        InferRequest { id, model: model.to_string(), deadline_ms, inputs };
                    let sent = Instant::now();
                    match client.infer(&req) {
                        Ok(Terminal::Output { .. }) => {
                            t.completed += 1;
                            t.latencies.push(sent.elapsed().as_secs_f64());
                        }
                        Ok(Terminal::Busy { .. }) => t.shed += 1,
                        Ok(Terminal::Error { code: ErrorCode::Expired, .. }) => t.expired += 1,
                        Ok(Terminal::Error { .. }) => t.errors += 1,
                        Err(_) => {
                            // Connection lost: account every remaining id
                            // so the report still sums to `n`.
                            t.errors += crate::util::ceil_div(n - id as usize, lanes) as u64;
                            break;
                        }
                    }
                    id += lanes as u64;
                }
                Ok(t)
            }));
        }
        for h in handles {
            tallies.push(
                h.join().unwrap_or_else(|_| Err(anyhow::anyhow!("load lane panicked"))),
            );
        }
    });

    let mut total = LaneTally::default();
    for t in tallies {
        let t = t?;
        total.completed += t.completed;
        total.shed += t.shed;
        total.expired += t.expired;
        total.errors += t.errors;
        total.latencies.extend(t.latencies);
    }
    Ok(LoadReport {
        submitted: n as u64,
        completed: total.completed,
        shed: total.shed,
        expired: total.expired,
        errors: total.errors,
        latency: Summary::of(&total.latencies),
        wall_s: start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn terminal_id_covers_all_variants() {
        let o = Terminal::Output { id: 1, batch_size: 1, outputs: Vec::new() };
        let b = Terminal::Busy { id: 2, retry_after_ms: 5 };
        let e = Terminal::Error { id: 3, code: ErrorCode::Engine, message: String::new() };
        assert_eq!(o.id(), 1);
        assert_eq!(b.id(), 2);
        assert_eq!(e.id(), 3);
    }

    #[test]
    fn synthetic_inputs_deterministic() {
        let shapes = vec![Shape::new(vec![2, 3])];
        let a = synthetic_request_inputs(&shapes, 7, 42);
        let b = synthetic_request_inputs(&shapes, 7, 42);
        let c = synthetic_request_inputs(&shapes, 7, 43);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a[0].shape().dims, vec![2, 3]);
    }
}
