//! The request router + worker pool: batches flow to the worker with the
//! fewest in-flight batches, each worker owning an inference [`Engine`]
//! that executes the whole batch in **one** batched call; responses are
//! collected with full latency accounting.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig};
use super::{Request, Response};
use crate::obs::{metrics, trace};
use crate::quant::Precision;
use crate::runtime::Engine;
use crate::util::stats::Summary;

/// Serving configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Worker threads (each with its own engine).
    pub workers: usize,
    /// Intra-engine execution threads. The coordinator itself only
    /// carries this; engine factories consult it when constructing
    /// [`Engine::par_interp`](crate::runtime::Engine::par_interp)-backed
    /// engines (one thread per emulated DSP unit, `1` = serial engines) —
    /// see the `serve --model` path in `main.rs`.
    pub engine_threads: usize,
    /// Numeric precision the engines execute at. Like `engine_threads`,
    /// the coordinator only carries it — engine factories consult it to
    /// build [`Engine::quant`](crate::runtime::Engine::quant) /
    /// INT8-cluster engines (`serve --precision int8`).
    pub precision: Precision,
    /// Batching policy.
    pub batcher: BatcherConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            engine_threads: 1,
            precision: Precision::F32,
            batcher: BatcherConfig::default(),
        }
    }
}

/// Aggregate serving metrics.
#[derive(Debug)]
pub struct ServeReport {
    /// Requests served.
    pub served: usize,
    /// Wall-clock duration of the run, seconds.
    pub wall_s: f64,
    /// Throughput, requests/second.
    pub throughput: f64,
    /// End-to-end latency stats (seconds).
    pub latency: Summary,
    /// Engine execution-time stats (seconds).
    pub exec: Summary,
    /// Queue-wait stats: submission → pulled by the batcher (seconds).
    pub queue: Summary,
    /// Batch-assembly stats: pulled → batch formed (seconds).
    pub assembly: Summary,
    /// Batch-size stats.
    pub batch_size: Summary,
    /// Mean batch occupancy as a fraction of `max_batch` (1.0 = every
    /// batch full).
    pub batch_fill: f64,
    /// Requests served by each worker (index = worker id).
    pub per_worker: Vec<usize>,
    /// All responses (outputs included), sorted by request id — ids are
    /// unique, so the ordering is deterministic regardless of how the
    /// worker pool interleaved completions.
    pub responses: Vec<Response>,
}

/// The serving coordinator.
pub struct Coordinator {
    cfg: ServeConfig,
}

impl Coordinator {
    /// Create a coordinator.
    pub fn new(cfg: ServeConfig) -> Coordinator {
        assert!(cfg.workers >= 1);
        Coordinator { cfg }
    }

    /// Serve every request produced by `requests` (an iterator that may
    /// sleep to model arrivals), constructing one engine per worker via
    /// `engine_factory` — **inside** the worker thread, because PJRT
    /// handles are not `Send`. Returns aggregate metrics once all
    /// responses are in.
    pub fn run<I>(
        &self,
        engine_factory: impl Fn(usize) -> Result<Engine> + Send + Sync,
        requests: I,
    ) -> Result<ServeReport>
    where
        I: IntoIterator<Item = Request> + Send,
        I::IntoIter: Send,
    {
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel::<Response>();
        let (ready_tx, ready_rx) = mpsc::channel::<bool>();
        let factory = &engine_factory;
        // Per-worker in-flight batch counts: the dispatcher routes each
        // batch to the least-loaded worker, and workers decrement when a
        // batch completes.
        let outstanding: Arc<Vec<AtomicUsize>> =
            Arc::new((0..self.cfg.workers).map(|_| AtomicUsize::new(0)).collect());
        let batches_formed = Arc::new(AtomicUsize::new(0));

        let t0 = Instant::now();
        thread::scope(|scope| -> Result<ServeReport> {
            let mut worker_txs = Vec::new();
            let mut handles = Vec::new();
            for w in 0..self.cfg.workers {
                let (btx, brx) = mpsc::channel::<super::Batch>();
                worker_txs.push(btx);
                let resp_tx = resp_tx.clone();
                let ready_tx = ready_tx.clone();
                let outstanding = outstanding.clone();
                handles.push(scope.spawn(move || -> Result<()> {
                    // Engine construction stays thread-local (PJRT clients
                    // and executables are !Send). Signal readiness so the
                    // feeder doesn't time requests against compile cost.
                    let engine = match factory(w) {
                        Ok(e) => {
                            let _ = ready_tx.send(true);
                            e
                        }
                        Err(e) => {
                            let _ = ready_tx.send(false);
                            return Err(e);
                        }
                    };
                    while let Ok(batch) = brx.recv() {
                        let bsize = batch.len();
                        let (opened, formed) = (batch.opened, batch.formed);
                        // One Stage span per batch: the exec slice of the
                        // serving timeline (queue/assembly are derived from
                        // the batch timestamps, not spanned — they happen
                        // on the dispatcher thread). The whole batch is one
                        // engine call, so the span measures real batched
                        // execution, not a per-request loop.
                        let _sp = trace::span("serve_batch", trace::Cat::Stage);
                        let mut reqs = batch.requests;
                        let inputs: Vec<Vec<crate::ops::Tensor>> =
                            reqs.iter_mut().map(|r| std::mem::take(&mut r.inputs)).collect();
                        match engine.infer_batch(&inputs) {
                            Ok(out) => {
                                for (req, outputs) in reqs.iter().zip(out.outputs) {
                                    // Stage split: time queued before the
                                    // batcher pulled the request, then time
                                    // held while the batch filled (a request
                                    // arriving mid-window has ~zero queue
                                    // time).
                                    let queue_s = opened
                                        .saturating_duration_since(req.submitted)
                                        .as_secs_f64();
                                    let assembly_s = formed
                                        .saturating_duration_since(req.submitted.max(opened))
                                        .as_secs_f64();
                                    let _ = resp_tx.send(Response {
                                        id: req.id,
                                        outputs,
                                        latency_s: req.submitted.elapsed().as_secs_f64(),
                                        exec_s: out.exec_s,
                                        queue_s,
                                        assembly_s,
                                        batch_size: bsize,
                                        worker: w,
                                    });
                                }
                            }
                            Err(e) => {
                                crate::xerror!("worker {w}: batch inference failed: {e:#}");
                            }
                        }
                        outstanding[w].fetch_sub(1, Ordering::Relaxed);
                    }
                    Ok(())
                }));
            }
            drop(resp_tx);

            // Dispatcher: batcher + least-outstanding-batches router.
            let batcher = Batcher::new(self.cfg.batcher);
            let n_workers = worker_txs.len();
            let route_counts = outstanding.clone();
            let formed_count = batches_formed.clone();
            let dispatcher = scope.spawn(move || {
                let mut rotation = 0usize;
                while let Some(batch) = batcher.next_batch(&req_rx) {
                    formed_count.fetch_add(1, Ordering::Relaxed);
                    // Route to the worker with the fewest in-flight
                    // batches: a worker stuck on a slow batch stops
                    // accumulating queue, unlike round-robin which keeps
                    // feeding it blindly. Ties rotate — breaking them by
                    // lowest rank would permanently starve higher-rank
                    // workers at low load, where every dispatch sees all
                    // counts at zero.
                    let w = super::pick_least_loaded(&route_counts[..], rotation);
                    rotation = rotation.wrapping_add(1);
                    route_counts[w].fetch_add(1, Ordering::Relaxed);
                    if worker_txs[w].send(batch).is_err() {
                        break;
                    }
                }
                // Dropping worker_txs closes the workers.
            });

            // Feed requests from the caller's iterator, once every worker
            // finished (or failed) engine construction — request latency
            // must not include one-time compilation.
            let n_workers = self.cfg.workers;
            let feeder = scope.spawn(move || {
                for _ in 0..n_workers {
                    let _ = ready_rx.recv();
                }
                let mut n = 0usize;
                for req in requests {
                    if req_tx.send(req).is_err() {
                        break;
                    }
                    n += 1;
                }
                n
            });

            let submitted = feeder.join().expect("feeder panicked");
            dispatcher.join().expect("dispatcher panicked");
            for h in handles {
                h.join().expect("worker panicked")?;
            }

            let mut responses: Vec<Response> = resp_rx.into_iter().collect();
            let wall_s = t0.elapsed().as_secs_f64();
            // Request ids are unique, so this total order is deterministic
            // under any multi-worker completion interleaving.
            responses.sort_by_key(|r| r.id);

            let mut per_worker = vec![0usize; self.cfg.workers];
            for r in &responses {
                per_worker[r.worker] += 1;
            }
            let lat: Vec<f64> = responses.iter().map(|r| r.latency_s).collect();
            let exec: Vec<f64> = responses.iter().map(|r| r.exec_s).collect();
            let queue: Vec<f64> = responses.iter().map(|r| r.queue_s).collect();
            let assembly: Vec<f64> = responses.iter().map(|r| r.assembly_s).collect();
            let bs: Vec<f64> = responses.iter().map(|r| r.batch_size as f64).collect();
            anyhow::ensure!(
                responses.len() == submitted,
                "served {} of {} requests",
                responses.len(),
                submitted
            );
            let throughput = responses.len() as f64 / wall_s.max(1e-12);
            let batches = batches_formed.load(Ordering::Relaxed);
            let batch_fill = if batches > 0 {
                (responses.len() as f64 / batches as f64) / self.cfg.batcher.max_batch as f64
            } else {
                0.0
            };
            // Per-sample amortized execution: each response's exec_s is
            // the whole batch's engine time, so divide by its batch size.
            let per_sample_exec: Vec<f64> = responses
                .iter()
                .map(|r| r.exec_s / (r.batch_size.max(1) as f64))
                .collect();
            // Publish the run to the metrics registry (the `serve.*`
            // namespace) so `--metrics-out` and the profile verb see the
            // same numbers the report prints.
            metrics::counter_set("serve.served", responses.len() as u64);
            metrics::gauge_set("serve.throughput_rps", throughput);
            metrics::gauge_set("serve.batch.fill", batch_fill);
            metrics::observe_all("serve.batch.per_sample_exec_s", &per_sample_exec);
            metrics::observe_all("serve.latency_s", &lat);
            metrics::observe_all("serve.exec_s", &exec);
            metrics::observe_all("serve.queue_s", &queue);
            metrics::observe_all("serve.assembly_s", &assembly);
            Ok(ServeReport {
                served: responses.len(),
                wall_s,
                throughput,
                latency: Summary::of(&lat).unwrap_or(EMPTY),
                exec: Summary::of(&exec).unwrap_or(EMPTY),
                queue: Summary::of(&queue).unwrap_or(EMPTY),
                assembly: Summary::of(&assembly).unwrap_or(EMPTY),
                batch_size: Summary::of(&bs).unwrap_or(EMPTY),
                batch_fill,
                per_worker,
                responses,
            })
        })
    }
}

const EMPTY: Summary = Summary {
    n: 0,
    mean: 0.0,
    stddev: 0.0,
    min: 0.0,
    p50: 0.0,
    p90: 0.0,
    p95: 0.0,
    p99: 0.0,
    max: 0.0,
};

/// Generate `n` synthetic requests for an engine's input shapes, with
/// exponential inter-arrival times at `rate` req/s (0 = all at once).
pub fn synthetic_requests(
    shapes: Vec<crate::graph::Shape>,
    n: usize,
    rate: f64,
    seed: u64,
) -> impl Iterator<Item = Request> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..n as u64).map(move |id| {
        if rate > 0.0 {
            let dt = rng.exp(rate);
            thread::sleep(std::time::Duration::from_secs_f64(dt.min(0.05)));
        }
        let inputs = shapes
            .iter()
            .map(|s| {
                let numel = s.numel();
                crate::ops::Tensor::new(
                    crate::graph::TensorDesc::plain(s.clone()),
                    rng.vec_uniform(numel),
                )
            })
            .collect();
        Request { id, inputs, submitted: Instant::now() }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Shape};
    use std::sync::Arc;

    fn engine() -> Engine {
        let mut b = GraphBuilder::new("serve_test");
        let x = b.input("x", Shape::nchw(1, 2, 8, 8));
        let c = b.conv("c", x, 4, 3, 1, 1);
        let r = b.relu("r", c);
        b.output(r);
        Engine::interp(Arc::new(b.finish()))
    }

    #[test]
    fn serves_all_requests_exactly_once() {
        let coord = Coordinator::new(ServeConfig::default());
        let shapes = engine().input_shapes();
        let report = coord
            .run(|_| Ok(engine()), synthetic_requests(shapes, 40, 0.0, 1))
            .unwrap();
        assert_eq!(report.served, 40);
        // ids 0..40 each exactly once
        let ids: Vec<u64> = report.responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
        assert!(report.throughput > 0.0);
    }

    #[test]
    fn batch_sizes_respect_cap() {
        let cfg = ServeConfig {
            workers: 1,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(10),
            },
            ..Default::default()
        };
        let coord = Coordinator::new(cfg);
        let shapes = engine().input_shapes();
        let report = coord
            .run(|_| Ok(engine()), synthetic_requests(shapes, 32, 0.0, 2))
            .unwrap();
        assert!(report.batch_size.max <= 4.0);
        assert!(report.batch_size.mean >= 1.0);
    }

    #[test]
    fn multiple_workers_share_load() {
        let cfg = ServeConfig { workers: 3, ..Default::default() };
        let coord = Coordinator::new(cfg);
        let shapes = engine().input_shapes();
        let report = coord
            .run(|_| Ok(engine()), synthetic_requests(shapes, 60, 0.0, 3))
            .unwrap();
        let mut seen = std::collections::HashSet::new();
        for r in &report.responses {
            seen.insert(r.worker);
        }
        assert!(seen.len() >= 2, "load should reach >1 worker: {seen:?}");
    }

    #[test]
    fn response_order_is_deterministic_and_workers_accounted() {
        let cfg = ServeConfig { workers: 3, ..Default::default() };
        let ids_of = |seed: u64| -> (Vec<u64>, Vec<usize>) {
            let coord = Coordinator::new(cfg);
            let shapes = engine().input_shapes();
            let report = coord
                .run(|_| Ok(engine()), synthetic_requests(shapes, 48, 0.0, seed))
                .unwrap();
            (report.responses.iter().map(|r| r.id).collect(), report.per_worker)
        };
        let (ids_a, pw_a) = ids_of(7);
        let (ids_b, pw_b) = ids_of(7);
        // Ordering never depends on which worker finished first.
        assert_eq!(ids_a, ids_b);
        assert_eq!(ids_a, (0..48).collect::<Vec<_>>());
        assert_eq!(pw_a.len(), 3);
        assert_eq!(pw_a.iter().sum::<usize>(), 48);
        assert_eq!(pw_b.iter().sum::<usize>(), 48);
    }

    #[test]
    fn batched_serving_matches_per_request_outputs() {
        // The worker executes each batch as ONE engine call; outputs must
        // still be what a per-request engine would have produced.
        let cfg = ServeConfig {
            workers: 1,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(20),
            },
            ..Default::default()
        };
        let report = Coordinator::new(cfg)
            .run(|_| Ok(engine()), synthetic_requests(engine().input_shapes(), 12, 0.0, 11))
            .unwrap();
        assert_eq!(report.served, 12);
        assert!(report.batch_fill > 0.0 && report.batch_fill <= 1.0);
        let solo = engine();
        // Re-derive each request's inputs from the same seeded stream the
        // synthetic generator used, and check the served outputs match a
        // fresh single-sample inference bit-for-bit.
        let inputs: Vec<Vec<crate::ops::Tensor>> =
            synthetic_requests(engine().input_shapes(), 12, 0.0, 11)
                .map(|r| r.inputs)
                .collect();
        for (resp, ins) in report.responses.iter().zip(&inputs) {
            let want = solo.infer(ins).unwrap();
            assert_eq!(resp.outputs[0].data, want.outputs[0].data);
            assert!(resp.exec_s >= 0.0 && resp.batch_size >= 1);
        }
    }

    #[test]
    fn latency_percentiles_are_ordered() {
        let coord = Coordinator::new(ServeConfig::default());
        let shapes = engine().input_shapes();
        let report = coord
            .run(|_| Ok(engine()), synthetic_requests(shapes, 30, 0.0, 9))
            .unwrap();
        let l = &report.latency;
        assert!(l.min <= l.p50 && l.p50 <= l.p90);
        assert!(l.p90 <= l.p95 && l.p95 <= l.p99 && l.p99 <= l.max);
    }

    #[test]
    fn stage_breakdown_is_recorded() {
        let coord = Coordinator::new(ServeConfig::default());
        let shapes = engine().input_shapes();
        let report = coord
            .run(|_| Ok(engine()), synthetic_requests(shapes, 20, 0.0, 6))
            .unwrap();
        assert_eq!(report.queue.n, 20);
        assert_eq!(report.assembly.n, 20);
        for r in &report.responses {
            assert!(r.queue_s >= 0.0 && r.assembly_s >= 0.0);
            // queue + assembly is submit→formed, a prefix of the
            // end-to-end latency.
            assert!(
                r.queue_s + r.assembly_s <= r.latency_s + 1e-6,
                "stages must fit inside the end-to-end latency"
            );
        }
    }

    #[test]
    fn latency_includes_queue_time() {
        let report = Coordinator::new(ServeConfig::default())
            .run(
                |_| Ok(engine()),
                synthetic_requests(engine().input_shapes(), 10, 0.0, 4),
            )
            .unwrap();
        for r in &report.responses {
            assert!(r.latency_s >= r.exec_s * 0.5, "latency must cover exec");
        }
    }
}
