//! Differential suite for the INT8 quantization subsystem (`quant`):
//!
//! * **Engine bit-exactness** — the serial [`QuantEngine`], the
//!   worker-pool engine and the quantized d-Xenos cluster must produce
//!   element-wise *identical* outputs for every scheme, sync mode and
//!   cluster size (exact integer accumulation + grid-snapped i8
//!   activation payloads make this equality exact, not approximate).
//! * **Accuracy envelope** — quantized output must track the f32
//!   interpreter within a generous documented tolerance on every zoo
//!   model (`xenos quantize --model M` prints the measured error).
//! * **Calibration determinism** — the same calibration set yields a
//!   byte-identical serialized table.
//! * **Saturation guard** — adversarial inputs at and beyond the ±range
//!   boundary saturate to ±127 without overflow, identically on every
//!   engine.
//! * **Wire format** — INT8 runs ship halo and all-gather payloads as
//!   `TAG_Q8` byte frames, one byte per element (asserted at the
//!   transport level with a recording wrapper).

use std::sync::{Arc, Mutex};

use xenos::dist::exec::wire::TAG_Q8;
use xenos::dist::exec::{
    plan_cluster, quant_row_offset, ClusterDriver, LocalTransport, ShardParams, ShardWorker,
    Transport, TransportResult,
};
use xenos::dist::{PartitionScheme, SyncMode};
use xenos::graph::{models, Graph, GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::ops::interp::synthetic_inputs;
use xenos::ops::params::ParamStore;
use xenos::ops::{Interpreter, Tensor};
use xenos::quant::{quantize_slice, scale_for, CalibTable, QuantEngine, QuantRun};
use xenos::runtime::Engine;
use xenos::serve::{self, BatcherConfig, Coordinator, ServeConfig};

/// Small CNN covering dense/pointwise/depthwise convs, both pool kinds,
/// shuffle/slice/concat/upsample, global pooling, FC and softmax — every
/// copy-op and conv path the quantized kernels implement.
fn small_cnn() -> Graph {
    let mut b = GraphBuilder::new("quant_cnn");
    let x = b.input("x", Shape::nchw(1, 4, 16, 16));
    let c1 = b.conv_bn_relu("c1", x, 16, 3, 1, 1);
    let dw = b.dw_bn_relu("dw", c1, 3, 1, 1);
    let pw = b.conv_bn_relu("pw", dw, 32, 1, 1, 0);
    let mp = b.maxpool("mp", pw, 2, 2);
    let sh = b.channel_shuffle("sh", mp, 4);
    let lo = b.slice_c("lo", sh, 0, 16);
    let hi = b.slice_c("hi", sh, 16, 32);
    let cat = b.concat("cat", &[lo, hi]);
    let up = b.upsample("up", cat, 2);
    let ap = b.avgpool("ap", up, 2, 2);
    let gp = b.global_pool("gp", ap);
    let fc = b.fc("fc", gp, 10);
    let sm = b.softmax("sm", fc);
    b.output(sm);
    b.finish()
}

fn calib_for(g: &Graph) -> CalibTable {
    let params = ParamStore::for_graph(g);
    CalibTable::synthetic(g, &params, 4, 1000)
}

/// Quantized single-device (serial + pooled) and cluster outputs must be
/// bit-identical across every scheme/size/sync combination.
fn assert_quant_engines_bit_identical(g: &Graph, seed: u64) {
    let ga = Arc::new(g.clone());
    let calib = calib_for(g);
    let inputs = synthetic_inputs(g, seed);
    let want = QuantEngine::new(ga.clone(), &calib, 1).expect("quant engine").run(&inputs);
    for workers in [2usize, 4] {
        let engine = QuantEngine::new(ga.clone(), &calib, workers).expect("quant engine");
        let got = engine.run(&inputs);
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.data, b.data, "{}: quant x{workers} diverged", g.name);
        }
    }
    let d = presets::tms320c6678();
    for scheme in [
        PartitionScheme::Mix,
        PartitionScheme::OutC,
        PartitionScheme::InH,
        PartitionScheme::InW,
    ] {
        for p in [2usize, 3] {
            for sync in [SyncMode::Ring, SyncMode::Ps] {
                // threads > 1 exercises the worker-pool-chunked quantized
                // shard kernels (ROADMAP follow-up (d)) — still bit-exact.
                for threads in [1usize, 2] {
                    let driver =
                        ClusterDriver::local_q8(ga.clone(), &d, p, scheme, sync, threads, &calib)
                            .expect("quant cluster spins up");
                    let got = driver.infer(&inputs).expect("quant cluster inference");
                    assert_eq!(want.len(), got.len());
                    for (a, b) in want.iter().zip(&got) {
                        assert_eq!(
                            a.data, b.data,
                            "{}: {scheme:?} p={p} {sync:?} t={threads} diverged from \
                             single-device quant",
                            g.name
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn quant_engines_bit_identical_on_cnn() {
    assert_quant_engines_bit_identical(&small_cnn(), 60);
}

#[test]
fn quant_engines_bit_identical_on_fused_graph() {
    // The fused CBR family takes the dedicated IntDot epilogues.
    let (fused, n) = xenos::opt::fusion::fuse_cbr(&small_cnn());
    assert!(n > 0, "fusion must produce CBR nodes");
    assert_quant_engines_bit_identical(&fused, 61);
}

#[test]
fn quant_engines_bit_identical_on_fully_optimized_graph() {
    // The full Xenos pipeline (fusion + linking) emits CBRA/CBRM linked
    // operators — the remaining IntDot epilogue (conv → bn/relu → pool).
    let g = small_cnn();
    let d = presets::tms320c6678();
    let o = xenos::opt::optimize(
        &g,
        &d,
        xenos::opt::OptimizeOptions { level: xenos::opt::OptLevel::Full, search: false },
    );
    assert_quant_engines_bit_identical(&o.graph, 67);
}

/// The tentpole acceptance property: on a fused MobileNet-style chain
/// every `IntDot → IntDot` edge stays i8-resident — **zero** snap
/// round-trips — on the serial engine, the worker-pool engine and every
/// cluster rank, while all of them agree bit-for-bit.
#[test]
fn integer_dataflow_has_zero_snap_roundtrips_across_engines() {
    let (fused, nf) = xenos::opt::fusion::fuse_cbr(&small_cnn());
    assert!(nf > 0, "fusion must produce CBR nodes");
    let g = Arc::new(fused);
    let calib = calib_for(&g);
    let inputs = synthetic_inputs(&g, 70);

    let serial = QuantEngine::new(g.clone(), &calib, 1).expect("quant engine");
    let want = serial.run(&inputs);
    assert_eq!(serial.snap_roundtrips(), 0, "serial engine round-tripped an integer edge");
    let pooled = QuantEngine::new(g.clone(), &calib, 4).expect("quant engine");
    let got = pooled.run(&inputs);
    assert_eq!(pooled.snap_roundtrips(), 0, "pooled engine round-tripped an integer edge");
    for (a, b) in want.iter().zip(&got) {
        assert_eq!(a.data, b.data, "pooled engine diverged");
    }

    // Cluster ranks, built by hand so each rank's QuantRun is inspectable
    // (threads = 2 also exercises the chunked quantized shard kernels).
    let d = presets::tms320c6678();
    let p = 2usize;
    for scheme in [PartitionScheme::Mix, PartitionScheme::OutC, PartitionScheme::InH] {
        let plan = plan_cluster(&g, &d, p, scheme, SyncMode::Ring);
        let master = ParamStore::for_graph(&g);
        let mut workers = Vec::new();
        let mut runs = Vec::new();
        for (rank, t) in LocalTransport::mesh(p).into_iter().enumerate() {
            let shard = ShardParams::extract(&g, &plan, &master, rank);
            let quant = Arc::new(QuantRun::build_with_offsets(
                &g,
                &calib,
                |id| shard.get(id),
                |id| quant_row_offset(&g, &plan, rank, id),
            ));
            runs.push(quant.clone());
            workers.push(ShardWorker::with_quant(
                g.clone(),
                plan.clone(),
                shard,
                Box::new(t),
                2,
                Some(quant),
            ));
        }
        let outs: Vec<Vec<Tensor>> = std::thread::scope(|scope| {
            let handles: Vec<_> = workers
                .into_iter()
                .map(|w| {
                    let inputs = inputs.clone();
                    scope.spawn(move || w.run(&inputs).expect("shard round"))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank")).collect()
        });
        for (rank, got) in outs.iter().enumerate() {
            assert_eq!(got[0].data, want[0].data, "{scheme:?}: rank {rank} diverged");
        }
        for (rank, run) in runs.iter().enumerate() {
            assert_eq!(
                run.snap_roundtrips(),
                0,
                "{scheme:?}: rank {rank} round-tripped an integer edge"
            );
        }
    }
}

#[test]
fn quant_tracks_f32_within_documented_envelope() {
    // Loose envelope: |int8 - f32| <= 0.25 + 0.25 * ||f32||_inf per
    // model. The measured per-model errors are recorded in EXPERIMENTS.md
    // (regenerate with `xenos quantize --model M`).
    for name in models::PAPER_BENCHMARKS {
        let g = models::by_name(name).expect("zoo model");
        let calib = calib_for(&g);
        let ga = Arc::new(g.clone());
        let q = QuantEngine::new(ga, &calib, 2).expect("quant engine");
        let inputs = synthetic_inputs(&g, 62);
        let fo = Interpreter::new(&g).run(&inputs);
        let qo = q.run(&inputs);
        assert_eq!(fo.len(), qo.len(), "{name}: output arity");
        for (a, b) in fo.iter().zip(&qo) {
            assert!(b.data.iter().all(|v| v.is_finite()), "{name}: non-finite int8 output");
            let fmax = a.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let bound = 0.25 + 0.25 * fmax;
            let diff = a.max_abs_diff(b);
            assert!(diff <= bound, "{name}: int8 drifted {diff} (bound {bound})");
        }
    }
}

#[test]
fn calibration_is_deterministic() {
    // Same calibration set -> byte-identical serialized scales.
    let g = small_cnn();
    let params = ParamStore::for_graph(&g);
    let a = CalibTable::synthetic(&g, &params, 3, 7).encode();
    let b = CalibTable::synthetic(&g, &params, 3, 7).encode();
    assert_eq!(a, b, "calibration must be reproducible byte-for-byte");
    // A different calibration set must (generically) differ.
    let c = CalibTable::synthetic(&g, &params, 3, 8).encode();
    assert_ne!(a, c, "different calibration inputs should move the ranges");
    // And the file round-trip preserves the bytes.
    let table = CalibTable::decode(&a).unwrap();
    assert_eq!(table.encode(), a);
}

#[test]
fn saturation_guard_on_adversarial_inputs() {
    // Inputs at exactly the calibrated boundary hit q = ±127; inputs far
    // beyond it must saturate (not wrap) and every engine must agree.
    let s = scale_for(1.0);
    assert_eq!(quantize_slice(&[1.0, -1.0, 2.0, -2.0, 1e30, -1e30], s), vec![
        127, -127, 127, -127, 127, -127
    ]);

    let mut b = GraphBuilder::new("sat_cnn");
    let x = b.input("x", Shape::nchw(1, 4, 8, 8));
    let c = b.conv_bn_relu("c", x, 8, 3, 1, 1);
    let gp = b.global_pool("gp", c);
    let f = b.fc("fc", gp, 4);
    b.output(f);
    let g = Arc::new(b.finish());
    let calib = calib_for(&g);

    // Adversarial input: every value at a ±range boundary or far outside.
    let shape = Shape::nchw(1, 4, 8, 8);
    let n = shape.numel();
    let data: Vec<f32> = (0..n)
        .map(|i| match i % 4 {
            0 => 1.0,
            1 => -1.0,
            2 => 1e6,
            _ => -1e6,
        })
        .collect();
    let adversarial = vec![Tensor::new(xenos::graph::TensorDesc::plain(shape), data)];
    let serial = QuantEngine::new(g.clone(), &calib, 1).unwrap().run(&adversarial);
    assert!(
        serial[0].data.iter().all(|v| v.is_finite()),
        "saturated inputs must not overflow the integer kernels"
    );
    let pooled = QuantEngine::new(g.clone(), &calib, 4).unwrap().run(&adversarial);
    assert_eq!(serial[0].data, pooled[0].data, "saturation must chunk identically");
    let d = presets::tms320c6678();
    let driver =
        ClusterDriver::local_q8(g, &d, 2, PartitionScheme::Mix, SyncMode::Ring, 1, &calib)
            .unwrap();
    let cluster = driver.infer(&adversarial).unwrap();
    assert_eq!(serial[0].data, cluster[0].data, "saturation must shard identically");
}

/// A transport wrapper that records every peer-link send (tag, payload
/// length in elements/bytes, and whether it was a byte frame).
struct Recording {
    inner: LocalTransport,
    log: Arc<Mutex<Vec<(u64, usize, bool)>>>,
}

impl Transport for Recording {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn world(&self) -> usize {
        self.inner.world()
    }

    fn send(&self, to: usize, tag: u64, data: &[f32]) -> TransportResult<()> {
        self.log.lock().unwrap().push((tag, data.len(), false));
        self.inner.send(to, tag, data)
    }

    fn recv(&self, from: usize, tag: u64) -> TransportResult<Vec<f32>> {
        self.inner.recv(from, tag)
    }

    fn send_bytes(&self, to: usize, tag: u64, data: &[u8]) -> TransportResult<()> {
        self.log.lock().unwrap().push((tag, data.len(), true));
        self.inner.send_bytes(to, tag, data)
    }

    fn recv_bytes(&self, from: usize, tag: u64) -> TransportResult<Vec<u8>> {
        self.inner.recv_bytes(from, tag)
    }

    fn abort(&self, culprit: Option<usize>, reason: &str) {
        self.inner.abort(culprit, reason);
    }

    fn sever(&self) {
        self.inner.sever();
    }
}

/// Two ranks, InH scheme over a conv→relu→conv chain: the second conv
/// pulls halo rows, the replicated head forces a spatial all-gather. In
/// INT8 mode every peer-link payload must be a `TAG_Q8` byte frame — one
/// byte per element — and the run must still match the single-device
/// quantized engine bit-for-bit.
#[test]
fn int8_halo_and_gather_frames_carry_i8_payloads() {
    let mut b = GraphBuilder::new("halo_q8");
    let x = b.input("x", Shape::nchw(1, 3, 12, 12));
    let c1 = b.conv("c1", x, 8, 3, 1, 1);
    let r = b.relu("r", c1);
    let c2 = b.conv("c2", r, 8, 3, 1, 1);
    let gp = b.global_pool("gp", c2);
    let f = b.fc("fc", gp, 4);
    b.output(f);
    let g = Arc::new(b.finish());

    let d = presets::tms320c6678();
    let p = 2usize;
    let plan = plan_cluster(&g, &d, p, PartitionScheme::InH, SyncMode::Ring);
    let master = ParamStore::for_graph(&g);
    let calib = calib_for(&g);
    let inputs = synthetic_inputs(&g, 63);
    let want = QuantEngine::new(g.clone(), &calib, 1).unwrap().run(&inputs);

    let log = Arc::new(Mutex::new(Vec::new()));
    let mut workers = Vec::new();
    for (rank, t) in LocalTransport::mesh(p).into_iter().enumerate() {
        let shard = ShardParams::extract(&g, &plan, &master, rank);
        let quant = Arc::new(QuantRun::build(&g, &calib, |id| shard.get(id)));
        let transport = Recording { inner: t, log: log.clone() };
        workers.push(ShardWorker::with_quant(
            g.clone(),
            plan.clone(),
            shard,
            Box::new(transport),
            1,
            Some(quant),
        ));
    }
    let outs: Vec<Vec<Tensor>> = std::thread::scope(|scope| {
        let handles: Vec<_> = workers
            .into_iter()
            .map(|w| {
                let inputs = inputs.clone();
                scope.spawn(move || w.run(&inputs).expect("shard round"))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank")).collect()
    });
    for (rank, got) in outs.iter().enumerate() {
        assert_eq!(got[0].data, want[0].data, "rank {rank} diverged from quant engine");
    }

    let log = log.lock().unwrap();
    assert!(!log.is_empty(), "the run must exchange activations");
    for &(tag, len, is_bytes) in log.iter() {
        assert!(is_bytes, "int8 run sent an f32 frame under tag {tag:#x}");
        assert!(tag & TAG_Q8 != 0, "byte frame without the TAG_Q8 kind: {tag:#x}");
        assert!(len > 0, "empty activation frame under tag {tag:#x}");
    }
    // Halo frames (c2 pulling boundary rows of r's slab): one byte per
    // element — a 12-wide, 8-channel row is 96 bytes, not 384.
    const TAG_HALO: u64 = 3 << 60;
    let halo: Vec<_> =
        log.iter().filter(|(tag, _, _)| tag & (3 << 60) == TAG_HALO).collect();
    assert!(!halo.is_empty(), "InH conv chain must exchange halos");
    for (_, len, _) in &halo {
        assert_eq!(*len % (8 * 12) as usize, 0, "halo frame is whole i8 rows");
    }
}

/// The f32 control: the same cluster without quantization ships f32
/// frames only (no TAG_Q8).
#[test]
fn f32_runs_do_not_use_q8_frames() {
    let mut b = GraphBuilder::new("halo_f32");
    let x = b.input("x", Shape::nchw(1, 3, 12, 12));
    let c1 = b.conv("c1", x, 8, 3, 1, 1);
    let r = b.relu("r", c1);
    let c2 = b.conv("c2", r, 8, 3, 1, 1);
    let gp = b.global_pool("gp", c2);
    b.output(gp);
    let g = Arc::new(b.finish());
    let d = presets::tms320c6678();
    let p = 2usize;
    let plan = plan_cluster(&g, &d, p, PartitionScheme::InH, SyncMode::Ring);
    let master = ParamStore::for_graph(&g);
    let inputs = synthetic_inputs(&g, 64);
    let log = Arc::new(Mutex::new(Vec::new()));
    let mut workers = Vec::new();
    for (rank, t) in LocalTransport::mesh(p).into_iter().enumerate() {
        let shard = ShardParams::extract(&g, &plan, &master, rank);
        let transport = Recording { inner: t, log: log.clone() };
        workers.push(ShardWorker::new(g.clone(), plan.clone(), shard, Box::new(transport), 1));
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = workers
            .into_iter()
            .map(|w| {
                let inputs = inputs.clone();
                scope.spawn(move || w.run(&inputs).expect("shard round"))
            })
            .collect();
        for h in handles {
            h.join().expect("rank");
        }
    });
    let log = log.lock().unwrap();
    assert!(!log.is_empty());
    for &(tag, _, is_bytes) in log.iter() {
        assert!(!is_bytes && tag & TAG_Q8 == 0, "f32 run leaked a q8 frame: {tag:#x}");
    }
}

/// `serve --precision int8` end to end: interp, par and cluster engines
/// behind the coordinator answer every request with identical outputs.
#[test]
fn serve_precision_int8_matrix_agrees_across_engines() {
    let g = Arc::new(small_cnn());
    let d = presets::tms320c6678();
    let calib = Arc::new(calib_for(&g));
    let shapes: Vec<Shape> =
        g.input_ids().iter().map(|&i| g.node(i).out.shape.clone()).collect();
    let n = 10usize;
    let mut reference: Option<Vec<Vec<f32>>> = None;
    for engine_kind in ["interp", "par", "cluster"] {
        let cfg = ServeConfig {
            workers: 2,
            engine_threads: 2,
            precision: xenos::quant::Precision::Int8,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_micros(200),
            },
        };
        let g2 = g.clone();
        let d2 = d.clone();
        let calib2 = calib.clone();
        let report = Coordinator::new(cfg)
            .run(
                move |_| match engine_kind {
                    "interp" => Engine::quant(g2.clone(), &calib2, 1),
                    "par" => Engine::quant(g2.clone(), &calib2, 2),
                    _ => {
                        let driver = ClusterDriver::local_q8(
                            g2.clone(),
                            &d2,
                            2,
                            PartitionScheme::Mix,
                            SyncMode::Ring,
                            1,
                            &calib2,
                        )?;
                        Ok(Engine::cluster(driver))
                    }
                },
                serve::coordinator::synthetic_requests(shapes.clone(), n, 0.0, 65),
            )
            .expect("int8 serve");
        assert_eq!(report.served, n, "engine={engine_kind}");
        let outs: Vec<Vec<f32>> =
            report.responses.iter().map(|r| r.outputs[0].data.clone()).collect();
        match &reference {
            None => reference = Some(outs),
            Some(want) => assert_eq!(want, &outs, "engine={engine_kind} diverged"),
        }
    }
}

/// The shard-resident partial-sum path: a 64 → 8-channel 1×1 bottleneck
/// where the planner keeps the wide activation resident and the narrow
/// dense conv consumes it by reduce-scattering exact i32 partial sums
/// (8·hw·4 B) instead of gathering the 64·hw·1 B input. Must be planned
/// (`ClusterPlan::partial`), must run at least one reduce-scatter, and
/// must stay bit-identical to the single-device quantized engine across
/// cluster sizes and sync modes.
#[test]
fn int8_partial_sum_bottleneck_is_bit_exact() {
    let mut b = GraphBuilder::new("quant_bneck");
    let x = b.input("x", Shape::nchw(1, 4, 8, 8));
    let c1 = b.conv("c1", x, 64, 3, 1, 1);
    let c2 = b.conv("c2", c1, 8, 1, 1, 0);
    let sm = b.softmax("sm", c2);
    b.output(sm);
    let g = Arc::new(b.finish());
    let calib = calib_for(&g);
    let inputs = synthetic_inputs(&g, 73);
    let want = QuantEngine::new(g.clone(), &calib, 1).unwrap().run(&inputs);
    let d = presets::tms320c6678();
    for p in [2usize, 3] {
        for sync in [SyncMode::Ring, SyncMode::Ps] {
            let driver =
                ClusterDriver::local_q8(g.clone(), &d, p, PartitionScheme::OutC, sync, 1, &calib)
                    .unwrap();
            assert!(
                driver.plan().partial.iter().any(|&f| f),
                "p={p} {sync:?}: the bottleneck must be planned partial-sum"
            );
            let acct = driver.plan().accounting(&g);
            assert!(acct.reduce_scatters >= 1, "p={p} {sync:?}: {acct:?}");
            assert!(acct.sync_bytes < acct.gathered_bytes, "p={p} {sync:?}: {acct:?}");
            let got = driver.infer(&inputs).unwrap();
            for (a, o) in want.iter().zip(&got) {
                assert_eq!(a.data, o.data, "p={p} {sync:?}: partial-sum diverged");
            }
            let stats = driver.sync_stats().unwrap();
            assert!(stats.reduce_scatters >= 1, "p={p} {sync:?}: {stats:?}");
        }
    }
}

/// Zoo acceptance matrix (heavier; run with --ignored in the quant-diff
/// CI job locally): quantized cluster bit-exact vs quantized single
/// device on real models.
#[test]
#[ignore]
fn zoo_quant_cluster_acceptance() {
    let d = presets::tms320c6678();
    for name in ["mobilenet", "resnet18", "shufflenet"] {
        let g = Arc::new(models::by_name(name).expect("zoo model"));
        let calib = calib_for(&g);
        let inputs = synthetic_inputs(&g, 66);
        let want = QuantEngine::new(g.clone(), &calib, 1).unwrap().run(&inputs);
        for scheme in [PartitionScheme::Mix, PartitionScheme::OutC] {
            let driver =
                ClusterDriver::local_q8(g.clone(), &d, 4, scheme, SyncMode::Ring, 1, &calib)
                    .unwrap();
            let got = driver.infer(&inputs).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.data, b.data, "{name}: {scheme:?} diverged");
            }
        }
    }
}
