//! Acceptance suite for the serving front door: overload accounting,
//! deadline expiry, graceful drain, multi-model routing, and the
//! malformed-frame negative paths. Everything runs over real loopback
//! sockets against an in-process [`IngestServer`].

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use xenos::graph::{GraphBuilder, Shape};
use xenos::ops::params::ParamStore;
use xenos::quant::CalibTable;
use xenos::runtime::Engine;
use xenos::serve::client::{synthetic_request_inputs, IngestClient, Terminal};
use xenos::serve::ingest::{self, ErrorCode, InferRequest};
use xenos::serve::server::{IngestConfig, IngestServer, ModelRegistry};
use xenos::serve::BatcherConfig;

/// Fast graph: one small conv + head, ~a millisecond per inference.
fn tiny_model() -> Arc<xenos::Graph> {
    let mut b = GraphBuilder::new("ingest_tiny");
    let x = b.input("x", Shape::nchw(1, 3, 16, 16));
    let c1 = b.conv_bn_relu("c1", x, 8, 3, 2, 1);
    let gp = b.global_pool("gp", c1);
    let fc = b.fc("fc", gp, 4);
    let sm = b.softmax("sm", fc);
    b.output(sm);
    Arc::new(b.finish())
}

/// Deliberately heavy graph (~tens of milliseconds per inference): stacked
/// wide convolutions, used to pin an engine busy while tests race it.
fn slow_model() -> Arc<xenos::Graph> {
    let mut b = GraphBuilder::new("ingest_slow");
    let x = b.input("x", Shape::nchw(1, 8, 32, 32));
    let c1 = b.conv_bn_relu("c1", x, 64, 3, 1, 1);
    let c2 = b.conv_bn_relu("c2", c1, 64, 3, 1, 1);
    let c3 = b.conv_bn_relu("c3", c2, 64, 3, 1, 1);
    let gp = b.global_pool("gp", c3);
    let fc = b.fc("fc", gp, 4);
    b.output(fc);
    Arc::new(b.finish())
}

fn input_shapes(g: &xenos::Graph) -> Vec<Shape> {
    g.input_ids().iter().map(|&i| g.node(i).out.shape.clone()).collect()
}

fn interp_registry(
    name: &str,
    g: &Arc<xenos::Graph>,
    workers: usize,
    batcher: BatcherConfig,
) -> ModelRegistry {
    let mut r = ModelRegistry::new();
    let graph = g.clone();
    r.register(name, input_shapes(g), workers, batcher, move |_w| {
        Ok(Engine::interp(graph.clone()))
    });
    r
}

fn addr_of(server: &IngestServer) -> String {
    server.local_addr().to_string()
}

/// Poll the stats until `pred` holds or the timeout trips.
fn wait_for(server: &IngestServer, pred: impl Fn(&xenos::serve::IngestStats) -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if pred(&server.stats()) {
            return;
        }
        assert!(Instant::now() < deadline, "stats predicate never held: {:?}", server.stats());
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// The archetype headline: saturate a queue of 4 with 12 pipelined
/// requests on one connection while the batch window holds every admitted
/// slot. Deterministically: exactly 4 outputs, exactly 8 busies, every id
/// answered exactly once — none dropped, none doubled.
#[test]
fn overload_sheds_deterministically_with_exact_accounting() {
    let g = tiny_model();
    let shapes = input_shapes(&g);
    // max_wait far above the client's send time: the first batch cannot
    // close (and release admission slots) until all 12 admission
    // decisions are made, so exactly queue_depth requests are admitted.
    let batcher = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(400) };
    let mut server = IngestServer::start(
        "127.0.0.1:0",
        interp_registry("m", &g, 1, batcher),
        IngestConfig { queue_depth: 4, read_timeout: Duration::from_secs(10) },
    )
    .expect("start");

    let mut client =
        IngestClient::connect(&addr_of(&server), Some(Duration::from_secs(10))).expect("connect");
    let n = 12u64;
    for id in 0..n {
        let req = InferRequest {
            id,
            model: "m".into(),
            deadline_ms: 0,
            inputs: synthetic_request_inputs(&shapes, 7, id),
        };
        client.send(&req).expect("send");
    }

    let mut seen = vec![0u32; n as usize];
    let (mut outputs, mut busies) = (0, 0);
    for _ in 0..n {
        match client.recv().expect("terminal") {
            Terminal::Output { id, batch_size, outputs: outs } => {
                outputs += 1;
                seen[id as usize] += 1;
                assert_eq!(batch_size, 4, "all admitted requests share one batch");
                assert!(!outs.is_empty());
            }
            Terminal::Busy { id, retry_after_ms } => {
                busies += 1;
                seen[id as usize] += 1;
                assert!((1..=1000).contains(&retry_after_ms), "hint {retry_after_ms}");
            }
            other => panic!("unexpected terminal {other:?}"),
        }
    }
    assert_eq!(outputs, 4, "queue depth admits exactly 4");
    assert_eq!(busies, 8, "the rest shed");
    assert!(seen.iter().all(|&c| c == 1), "every id exactly one terminal: {seen:?}");
    // The admitted ids are the first 4 — admission is in arrival order on
    // one connection.
    let stats = server.drain();
    assert_eq!(stats.submitted, 12);
    assert_eq!(stats.completed, 4);
    assert_eq!(stats.shed, 8);
    assert_eq!(stats.expired, 0);
    assert_eq!(
        stats.completed + stats.shed + stats.expired + stats.engine_errors,
        stats.submitted,
        "admission invariant"
    );
}

/// Sustained 2× overload through the load driver: 8 closed-loop lanes
/// against queue depth 4. Every request gets a terminal within the read
/// deadline (no lane errors), and the server's books balance.
#[test]
fn sustained_overload_accounting_balances() {
    let g = tiny_model();
    let shapes = input_shapes(&g);
    let batcher = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
    let mut server = IngestServer::start(
        "127.0.0.1:0",
        interp_registry("m", &g, 1, batcher),
        IngestConfig { queue_depth: 4, read_timeout: Duration::from_secs(10) },
    )
    .expect("start");

    let n = 64usize;
    let report = xenos::serve::client::drive_load(
        &addr_of(&server),
        "m",
        &shapes,
        n,
        8,
        0,
        Duration::from_secs(10),
        21,
    )
    .expect("drive");
    assert_eq!(report.submitted, n as u64);
    assert_eq!(report.errors, 0, "every terminal arrived within the read deadline");
    assert_eq!(
        report.completed + report.shed + report.expired,
        n as u64,
        "client-side accounting: {report:?}"
    );
    assert!(report.completed >= 1);

    let stats = server.drain();
    assert_eq!(stats.submitted, n as u64);
    assert_eq!(
        stats.completed + stats.shed + stats.expired + stats.engine_errors,
        stats.submitted,
        "server-side accounting: {stats:?}"
    );
    assert_eq!(stats.completed, report.completed);
    assert_eq!(stats.shed, report.shed);
}

/// Requests whose deadline passes while an engine is busy are dropped
/// with a typed error and never reach the engine.
#[test]
fn expired_requests_never_reach_an_engine() {
    let g = slow_model();
    let shapes = input_shapes(&g);
    let batcher = BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) };
    let mut server = IngestServer::start(
        "127.0.0.1:0",
        interp_registry("m", &g, 1, batcher),
        IngestConfig { queue_depth: 8, read_timeout: Duration::from_secs(10) },
    )
    .expect("start");
    let addr = addr_of(&server);

    // Pin the single worker on a no-deadline blocker.
    let mut blocker =
        IngestClient::connect(&addr, Some(Duration::from_secs(30))).expect("connect");
    blocker
        .send(&InferRequest {
            id: 100,
            model: "m".into(),
            deadline_ms: 0,
            inputs: synthetic_request_inputs(&shapes, 3, 100),
        })
        .expect("send blocker");
    wait_for(&server, |s| s.executed == 1);

    // While it runs, submit 4 requests that expire after 1 ms.
    let mut hasty =
        IngestClient::connect(&addr, Some(Duration::from_secs(30))).expect("connect");
    for id in 1..=4u64 {
        hasty
            .send(&InferRequest {
                id,
                model: "m".into(),
                deadline_ms: 1,
                inputs: synthetic_request_inputs(&shapes, 3, id),
            })
            .expect("send");
    }
    for _ in 0..4 {
        match hasty.recv().expect("terminal") {
            Terminal::Error { code: ErrorCode::Expired, .. } => {}
            other => panic!("expected expiry, got {other:?}"),
        }
    }
    match blocker.recv().expect("blocker terminal") {
        Terminal::Output { id: 100, .. } => {}
        other => panic!("expected blocker output, got {other:?}"),
    }

    let stats = server.drain();
    assert_eq!(stats.submitted, 5);
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.expired, 4);
    assert_eq!(stats.executed, 1, "expired work must not reach the engine");
}

/// Graceful drain: in-flight work completes and is answered; new
/// connections are refused once drain returns.
#[test]
fn drain_completes_in_flight_and_refuses_new_connects() {
    let g = slow_model();
    let shapes = input_shapes(&g);
    let batcher = BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) };
    let mut server = IngestServer::start(
        "127.0.0.1:0",
        interp_registry("m", &g, 1, batcher),
        IngestConfig { queue_depth: 4, read_timeout: Duration::from_secs(10) },
    )
    .expect("start");
    let addr = addr_of(&server);

    let mut client =
        IngestClient::connect(&addr, Some(Duration::from_secs(30))).expect("connect");
    client
        .send(&InferRequest {
            id: 7,
            model: "m".into(),
            deadline_ms: 0,
            inputs: synthetic_request_inputs(&shapes, 5, 7),
        })
        .expect("send");
    wait_for(&server, |s| s.executed == 1);

    let stats = server.drain();
    assert_eq!(stats.completed, 1, "drain answers in-flight work: {stats:?}");

    // The response was written during drain; it is still readable.
    match client.recv().expect("terminal after drain") {
        Terminal::Output { id: 7, .. } => {}
        other => panic!("expected output, got {other:?}"),
    }

    // The listener is gone: new connections are refused.
    let err = IngestClient::connect(&addr, Some(Duration::from_secs(1)));
    assert!(err.is_err(), "post-drain connect must fail");
}

/// Two models, one listener: interleaved requests route to the right
/// pools and return outputs bit-identical to direct `Engine::infer` runs
/// — F32 interpreter and INT8 quantized engine side by side.
#[test]
fn multi_model_routing_matches_direct_inference_at_both_precisions() {
    let ga = tiny_model();
    let gb = slow_model();
    let calib = CalibTable::synthetic(&gb, &ParamStore::for_graph(&gb), 4, 9);

    let mut registry = ModelRegistry::new();
    let batcher = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) };
    {
        let g = ga.clone();
        registry.register("alpha", input_shapes(&ga), 1, batcher, move |_w| {
            Ok(Engine::interp(g.clone()))
        });
    }
    {
        let g = gb.clone();
        let c = calib.clone();
        registry
            .register("beta", input_shapes(&gb), 1, batcher, move |_w| Engine::quant(g.clone(), &c, 1));
    }
    let mut server = IngestServer::start(
        "127.0.0.1:0",
        registry,
        IngestConfig { queue_depth: 32, read_timeout: Duration::from_secs(10) },
    )
    .expect("start");

    let ref_a = Engine::interp(ga.clone());
    let ref_b = Engine::quant(gb.clone(), &calib, 1).expect("quant engine");
    let shapes_a = input_shapes(&ga);
    let shapes_b = input_shapes(&gb);

    let mut client =
        IngestClient::connect(&addr_of(&server), Some(Duration::from_secs(30))).expect("connect");
    let n = 10u64;
    let mut expected: Vec<Vec<Vec<f32>>> = Vec::new();
    for id in 0..n {
        let (model, shapes, engine): (&str, &[Shape], &Engine) = if id % 2 == 0 {
            ("alpha", &shapes_a, &ref_a)
        } else {
            ("beta", &shapes_b, &ref_b)
        };
        let inputs = synthetic_request_inputs(shapes, 13, id);
        let direct = engine.infer(&inputs).expect("direct infer");
        expected.push(direct.outputs.iter().map(|t| t.data.clone()).collect());
        client
            .send(&InferRequest { id, model: model.into(), deadline_ms: 0, inputs })
            .expect("send");
    }
    let mut got: Vec<Option<Vec<Vec<f32>>>> = vec![None; n as usize];
    for _ in 0..n {
        match client.recv().expect("terminal") {
            Terminal::Output { id, outputs, .. } => {
                assert!(got[id as usize].is_none(), "double terminal for {id}");
                got[id as usize] = Some(outputs.iter().map(|t| t.data.clone()).collect());
            }
            other => panic!("unexpected terminal {other:?}"),
        }
    }
    for (id, (want, have)) in expected.iter().zip(&got).enumerate() {
        let have = have.as_ref().expect("terminal for every id");
        assert_eq!(want, have, "request {id}: served output must be bit-identical");
    }
    server.drain();
}

fn raw_header(tag: u64, len: u32) -> Vec<u8> {
    let mut h = Vec::with_capacity(12);
    h.extend_from_slice(&tag.to_le_bytes());
    h.extend_from_slice(&len.to_le_bytes());
    h
}

/// Read until EOF/reset — proof the server closed this connection.
fn assert_closed(stream: &mut TcpStream) {
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .expect("set_read_timeout");
    let mut buf = [0u8; 64];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => return,
            Ok(_) => continue, // drain any queued reply bytes
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::ConnectionReset | std::io::ErrorKind::BrokenPipe
                ) =>
            {
                return
            }
            Err(e) => panic!("expected server-side close, got {e}"),
        }
    }
}

/// A request that proves the server still serves fresh connections.
fn assert_alive(addr: &str, shapes: &[Shape]) {
    let mut client = IngestClient::connect(addr, Some(Duration::from_secs(10))).expect("connect");
    let req = InferRequest {
        id: 999,
        model: "m".into(),
        deadline_ms: 0,
        inputs: synthetic_request_inputs(shapes, 1, 999),
    };
    match client.infer(&req).expect("terminal") {
        Terminal::Output { id: 999, .. } => {}
        other => panic!("expected output, got {other:?}"),
    }
}

/// Malformed frames kill only the offending connection: oversized length
/// prefix, truncated frame, unknown model, undecodable payload, unknown
/// tag — after each, a fresh connection still gets served.
#[test]
fn malformed_frames_kill_only_their_connection() {
    let g = tiny_model();
    let shapes = input_shapes(&g);
    let batcher = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
    let mut server = IngestServer::start(
        "127.0.0.1:0",
        interp_registry("m", &g, 1, batcher),
        IngestConfig { queue_depth: 8, read_timeout: Duration::from_secs(2) },
    )
    .expect("start");
    let addr = addr_of(&server);

    // Oversized length prefix: rejected before allocation, connection dies.
    {
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.write_all(&raw_header(ingest::REQ_INFER, 600 << 20)).expect("write");
        assert_closed(&mut s);
        assert_alive(&addr, &shapes);
    }

    // Truncated frame: header promises 100 bytes, 10 arrive, then EOF.
    {
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.write_all(&raw_header(ingest::REQ_INFER, 100)).expect("write");
        s.write_all(&[0u8; 10]).expect("write");
        s.shutdown(Shutdown::Write).expect("shutdown");
        assert_closed(&mut s);
        assert_alive(&addr, &shapes);
    }

    // Undecodable payload: valid frame, garbage body → typed BadRequest,
    // then the connection closes.
    {
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.write_all(&raw_header(ingest::REQ_INFER, 3)).expect("write");
        s.write_all(&[1, 2, 3]).expect("write");
        let mut head = [0u8; 12];
        s.read_exact(&mut head).expect("reply header");
        let tag = u64::from_le_bytes(head[..8].try_into().unwrap());
        let len = u32::from_le_bytes(head[8..].try_into().unwrap()) as usize;
        let mut payload = vec![0u8; len];
        s.read_exact(&mut payload).expect("reply payload");
        assert_eq!(tag, ingest::RESP_ERROR);
        let (_, code, _) = ingest::decode_error(&payload).expect("decode");
        assert_eq!(code, ErrorCode::BadRequest);
        assert_closed(&mut s);
        assert_alive(&addr, &shapes);
    }

    // Unknown model: typed error, connection closes.
    {
        let mut client =
            IngestClient::connect(&addr, Some(Duration::from_secs(10))).expect("connect");
        let req = InferRequest {
            id: 5,
            model: "no-such-model".into(),
            deadline_ms: 0,
            inputs: synthetic_request_inputs(&shapes, 1, 5),
        };
        match client.infer(&req).expect("terminal") {
            Terminal::Error { id: 5, code: ErrorCode::UnknownModel, .. } => {}
            other => panic!("expected unknown-model error, got {other:?}"),
        }
        assert_alive(&addr, &shapes);
    }

    // Unknown tag: dropped connection, no reply.
    {
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.write_all(&raw_header(0xBAD0_0001, 0)).expect("write");
        assert_closed(&mut s);
        assert_alive(&addr, &shapes);
    }

    let stats = server.drain();
    assert!(stats.rejected >= 2, "bad payload + unknown model counted: {stats:?}");
    assert_eq!(
        stats.completed + stats.shed + stats.expired + stats.engine_errors,
        stats.submitted,
        "protocol errors never skew the admission books: {stats:?}"
    );
}

/// Wrong input shapes are a typed BadRequest, not an engine crash.
#[test]
fn mismatched_shapes_rejected_before_admission() {
    let g = tiny_model();
    let batcher = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
    let mut server = IngestServer::start(
        "127.0.0.1:0",
        interp_registry("m", &g, 1, batcher),
        IngestConfig { queue_depth: 8, read_timeout: Duration::from_secs(5) },
    )
    .expect("start");

    let bad_shapes = vec![Shape::nchw(1, 1, 4, 4)];
    let mut client =
        IngestClient::connect(&addr_of(&server), Some(Duration::from_secs(10))).expect("connect");
    let req = InferRequest {
        id: 1,
        model: "m".into(),
        deadline_ms: 0,
        inputs: synthetic_request_inputs(&bad_shapes, 1, 1),
    };
    match client.infer(&req).expect("terminal") {
        Terminal::Error { id: 1, code: ErrorCode::BadRequest, .. } => {}
        other => panic!("expected bad-request, got {other:?}"),
    }
    let stats = server.drain();
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.submitted, 0, "rejected requests never reach admission");
}
