//! Observability integration tests: the Chrome-trace export is
//! well-formed (balanced nesting per thread), the metrics registry is
//! pinned against the cluster's ground-truth counters, toggling the
//! recorder never changes the numerics, and the committed `BENCH_*.json`
//! artifacts stay schema-valid.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use xenos::dist::exec::ClusterDriver;
use xenos::dist::{PartitionScheme, SyncMode};
use xenos::graph::{Graph, GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::obs::{metrics, trace, Json};
use xenos::ops::interp::synthetic_inputs;
use xenos::runtime::Engine;
use xenos::util::bench::validate_bench_json;

/// The span recorder and the metrics registry are process-wide; every
/// test that touches them serializes on this lock.
fn obs_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|p| p.into_inner())
}

fn small_cnn() -> Graph {
    let mut b = GraphBuilder::new("obs_cnn");
    let x = b.input("x", Shape::nchw(1, 3, 16, 16));
    let c1 = b.conv_bn_relu("c1", x, 8, 3, 1, 1);
    let p = b.avgpool("p", c1, 2, 2);
    let c2 = b.conv_bn_relu("c2", p, 16, 3, 1, 1);
    let gp = b.global_pool("gp", c2);
    let f = b.fc("fc", gp, 10);
    let s = b.softmax("sm", f);
    b.output(s);
    b.finish()
}

/// Per `(pid, tid)`, complete (`ph: "X"`) events must be disjoint or
/// properly nested — a span never straddles its parent's end.
fn assert_balanced(doc: &Json) -> usize {
    let evs = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    let mut by_thread: BTreeMap<(u64, u64), Vec<(i64, i64)>> = BTreeMap::new();
    let mut n = 0usize;
    for e in evs {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let num = |k: &str| {
            e.get(k).and_then(Json::as_f64).unwrap_or_else(|| panic!("event missing {k}"))
        };
        let ts = num("ts") as i64;
        by_thread
            .entry((num("pid") as u64, num("tid") as u64))
            .or_default()
            .push((ts, ts + num("dur") as i64));
        n += 1;
    }
    for ((pid, tid), mut spans) in by_thread {
        spans.sort_unstable();
        let mut stack: Vec<i64> = Vec::new(); // end times of open spans
        for (ts, end) in spans {
            while matches!(stack.last(), Some(&e) if e <= ts) {
                stack.pop();
            }
            if let Some(&parent_end) = stack.last() {
                assert!(
                    end <= parent_end,
                    "rank {pid} tid {tid}: span [{ts}, {end}] straddles its \
                     parent (ends {parent_end})"
                );
            }
            stack.push(end);
        }
    }
    n
}

#[test]
fn cluster_chrome_trace_is_wellformed() {
    let _l = obs_lock();
    let g = small_cnn();
    let d = presets::tms320c6678();
    let driver = ClusterDriver::local(
        Arc::new(g.clone()),
        &d,
        2,
        PartitionScheme::Mix,
        SyncMode::Ring,
        1,
    )
    .expect("cluster spins up");
    let inputs = synthetic_inputs(&g, 11);
    trace::clear();
    trace::set_enabled(true);
    driver.infer(&inputs).expect("traced inference");
    trace::set_enabled(false);
    let events = trace::drain();
    trace::clear();

    assert!(events.iter().any(|e| e.cat == trace::Cat::Round), "no round span");
    assert!(events.iter().any(|e| e.cat == trace::Cat::Compute), "no compute spans");
    assert!(
        events.iter().any(|e| e.lane == 0) && events.iter().any(|e| e.lane == 1),
        "spans must cover both ranks"
    );

    // The document survives a serialize/parse round trip and stays
    // structurally sound (Perfetto rejects unbalanced nesting).
    let doc = trace::chrome_trace(&events);
    let parsed = Json::parse(&doc.to_pretty()).expect("chrome trace parses");
    assert_eq!(
        parsed.get("displayTimeUnit").and_then(Json::as_str),
        Some("ms"),
        "missing displayTimeUnit"
    );
    let n = assert_balanced(&parsed);
    assert_eq!(n, events.len(), "every span must appear as one X event");
}

#[test]
fn cluster_metrics_match_ground_truth() {
    let _l = obs_lock();
    let g = small_cnn();
    let d = presets::tms320c6678();
    let driver = ClusterDriver::local(
        Arc::new(g.clone()),
        &d,
        2,
        PartitionScheme::OutC,
        SyncMode::Ring,
        1,
    )
    .expect("cluster spins up");
    let inputs = synthetic_inputs(&g, 17);
    driver.infer(&inputs).expect("round 1");
    driver.infer(&inputs).expect("round 2");

    metrics::reset();
    driver.publish_metrics();
    let acc = driver.plan().accounting(&g);
    let stats = driver.sync_stats().expect("local cluster stats");
    assert!(acc.gathers_skipped >= 1, "OutC plan skipped nothing: {acc:?}");

    // Planner accounting, published verbatim.
    assert_eq!(
        metrics::counter_value("cluster.plan.gathers_skipped"),
        acc.gathers_skipped as u64
    );
    assert_eq!(metrics::counter_value("cluster.plan.all_gathers"), acc.all_gathers as u64);
    assert_eq!(metrics::counter_value("cluster.plan.sync_bytes"), acc.sync_bytes);
    // Measured rank-0 wire traffic, published verbatim.
    assert_eq!(metrics::counter_value("cluster.sync.bytes"), stats.sync_bytes);
    assert_eq!(metrics::counter_value("cluster.sync.gathers_skipped"), stats.gathers_skipped);
    assert_eq!(metrics::counter_value("cluster.sync.all_gathers"), stats.all_gathers);
    // Two rounds ran, so the runtime saw at least every plan-level skip.
    assert!(
        stats.gathers_skipped >= acc.gathers_skipped as u64,
        "measured skips below plan: {stats:?} vs {acc:?}"
    );

    // The JSON snapshot carries the same numbers.
    let snap = metrics::snapshot();
    let bytes = snap.get("cluster.sync.bytes").and_then(Json::as_f64).expect("snapshot key");
    assert_eq!(bytes as u64, stats.sync_bytes);
    assert_eq!(snap.get("cluster.world").and_then(Json::as_f64), Some(2.0));
    metrics::reset();
}

/// The mobilenet-sized variant of the pinning test — slow, run with
/// `cargo test -- --ignored` when touching the sync or metrics paths.
#[test]
#[ignore]
fn mobilenet_cluster_metrics_match_ground_truth() {
    let _l = obs_lock();
    let g = xenos::graph::models::mobilenet();
    let d = presets::tms320c6678();
    let driver = ClusterDriver::local(
        Arc::new(g.clone()),
        &d,
        2,
        PartitionScheme::Mix,
        SyncMode::Ring,
        2,
    )
    .expect("cluster spins up");
    let inputs = synthetic_inputs(&g, 23);
    driver.infer(&inputs).expect("inference");
    metrics::reset();
    driver.publish_metrics();
    let acc = driver.plan().accounting(&g);
    let stats = driver.sync_stats().expect("local cluster stats");
    assert_eq!(
        metrics::counter_value("cluster.plan.gathers_skipped"),
        acc.gathers_skipped as u64
    );
    assert_eq!(metrics::counter_value("cluster.sync.bytes"), stats.sync_bytes);
    metrics::reset();
}

#[test]
fn recorder_toggle_is_bit_exact() {
    let _l = obs_lock();
    let g = small_cnn();
    let inputs = synthetic_inputs(&g, 31);
    let ga = Arc::new(g.clone());
    let d = presets::tms320c6678();
    let engines = vec![
        Engine::interp(ga.clone()),
        Engine::par_interp(ga.clone(), &d, 2),
        Engine::cluster(
            ClusterDriver::local(ga.clone(), &d, 2, PartitionScheme::Mix, SyncMode::Ring, 1)
                .expect("cluster spins up"),
        ),
    ];
    for e in &engines {
        trace::set_enabled(false);
        trace::clear();
        let off = e.infer(&inputs).expect("untraced inference");
        assert!(trace::drain().is_empty(), "{}: disabled recorder captured spans", e.name());
        trace::set_enabled(true);
        let on = e.infer(&inputs).expect("traced inference");
        trace::set_enabled(false);
        assert!(!trace::drain().is_empty(), "{}: enabled recorder captured nothing", e.name());
        trace::clear();
        assert_eq!(off.outputs.len(), on.outputs.len());
        for (a, b) in off.outputs.iter().zip(&on.outputs) {
            assert_eq!(a.data, b.data, "{}: tracing changed the numerics", e.name());
        }
    }
}

#[test]
fn committed_bench_artifacts_are_schema_valid() {
    for name in ["BENCH_kernels.json", "BENCH_serve.json"] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
        let doc = Json::parse(&text).unwrap_or_else(|e| panic!("{name} does not parse: {e:#}"));
        let entries =
            validate_bench_json(&doc).unwrap_or_else(|e| panic!("{name} is invalid: {e:#}"));
        assert!(!entries.is_empty(), "{name} has no entries");
    }
}
