//! Observability integration tests: the Chrome-trace export is
//! well-formed (balanced nesting per thread), the metrics registry is
//! pinned against the cluster's ground-truth counters, toggling the
//! recorder never changes the numerics, and the committed `BENCH_*.json`
//! artifacts stay schema-valid.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use xenos::dist::exec::{plan_cluster_opts, plan_cluster_src, ClusterDriver};
use xenos::dist::{PartitionScheme, SyncMode};
use xenos::graph::{Graph, GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::obs::profile::op_signature;
use xenos::obs::{metrics, trace, CostSource, DriftReport, Json, ProfileDb};
use xenos::ops::interp::synthetic_inputs;
use xenos::quant::Precision;
use xenos::runtime::Engine;
use xenos::util::bench::validate_bench_json;

/// The span recorder and the metrics registry are process-wide; every
/// test that touches them serializes on this lock.
fn obs_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|p| p.into_inner())
}

fn small_cnn() -> Graph {
    let mut b = GraphBuilder::new("obs_cnn");
    let x = b.input("x", Shape::nchw(1, 3, 16, 16));
    let c1 = b.conv_bn_relu("c1", x, 8, 3, 1, 1);
    let p = b.avgpool("p", c1, 2, 2);
    let c2 = b.conv_bn_relu("c2", p, 16, 3, 1, 1);
    let gp = b.global_pool("gp", c2);
    let f = b.fc("fc", gp, 10);
    let s = b.softmax("sm", f);
    b.output(s);
    b.finish()
}

/// Per `(pid, tid)`, complete (`ph: "X"`) events must be disjoint or
/// properly nested — a span never straddles its parent's end.
fn assert_balanced(doc: &Json) -> usize {
    let evs = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    let mut by_thread: BTreeMap<(u64, u64), Vec<(i64, i64)>> = BTreeMap::new();
    let mut n = 0usize;
    for e in evs {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let num = |k: &str| {
            e.get(k).and_then(Json::as_f64).unwrap_or_else(|| panic!("event missing {k}"))
        };
        let ts = num("ts") as i64;
        by_thread
            .entry((num("pid") as u64, num("tid") as u64))
            .or_default()
            .push((ts, ts + num("dur") as i64));
        n += 1;
    }
    for ((pid, tid), mut spans) in by_thread {
        spans.sort_unstable();
        let mut stack: Vec<i64> = Vec::new(); // end times of open spans
        for (ts, end) in spans {
            while matches!(stack.last(), Some(&e) if e <= ts) {
                stack.pop();
            }
            if let Some(&parent_end) = stack.last() {
                assert!(
                    end <= parent_end,
                    "rank {pid} tid {tid}: span [{ts}, {end}] straddles its \
                     parent (ends {parent_end})"
                );
            }
            stack.push(end);
        }
    }
    n
}

#[test]
fn cluster_chrome_trace_is_wellformed() {
    let _l = obs_lock();
    let g = small_cnn();
    let d = presets::tms320c6678();
    let driver = ClusterDriver::local(
        Arc::new(g.clone()),
        &d,
        2,
        PartitionScheme::Mix,
        SyncMode::Ring,
        1,
    )
    .expect("cluster spins up");
    let inputs = synthetic_inputs(&g, 11);
    trace::clear();
    trace::set_enabled(true);
    driver.infer(&inputs).expect("traced inference");
    trace::set_enabled(false);
    let events = trace::drain();
    trace::clear();

    assert!(events.iter().any(|e| e.cat == trace::Cat::Round), "no round span");
    assert!(events.iter().any(|e| e.cat == trace::Cat::Compute), "no compute spans");
    assert!(
        events.iter().any(|e| e.lane == 0) && events.iter().any(|e| e.lane == 1),
        "spans must cover both ranks"
    );

    // The document survives a serialize/parse round trip and stays
    // structurally sound (Perfetto rejects unbalanced nesting).
    let doc = trace::chrome_trace(&events);
    let parsed = Json::parse(&doc.to_pretty()).expect("chrome trace parses");
    assert_eq!(
        parsed.get("displayTimeUnit").and_then(Json::as_str),
        Some("ms"),
        "missing displayTimeUnit"
    );
    let n = assert_balanced(&parsed);
    assert_eq!(n, events.len(), "every span must appear as one X event");
}

#[test]
fn cluster_metrics_match_ground_truth() {
    let _l = obs_lock();
    let g = small_cnn();
    let d = presets::tms320c6678();
    let driver = ClusterDriver::local(
        Arc::new(g.clone()),
        &d,
        2,
        PartitionScheme::OutC,
        SyncMode::Ring,
        1,
    )
    .expect("cluster spins up");
    let inputs = synthetic_inputs(&g, 17);
    driver.infer(&inputs).expect("round 1");
    driver.infer(&inputs).expect("round 2");

    metrics::reset();
    driver.publish_metrics();
    let acc = driver.plan().accounting(&g);
    let stats = driver.sync_stats().expect("local cluster stats");
    assert!(acc.gathers_skipped >= 1, "OutC plan skipped nothing: {acc:?}");

    // Planner accounting, published verbatim.
    assert_eq!(
        metrics::counter_value("cluster.plan.gathers_skipped"),
        acc.gathers_skipped as u64
    );
    assert_eq!(metrics::counter_value("cluster.plan.all_gathers"), acc.all_gathers as u64);
    assert_eq!(metrics::counter_value("cluster.plan.sync_bytes"), acc.sync_bytes);
    // Measured rank-0 wire traffic, published verbatim.
    assert_eq!(metrics::counter_value("cluster.sync.bytes"), stats.sync_bytes);
    assert_eq!(metrics::counter_value("cluster.sync.gathers_skipped"), stats.gathers_skipped);
    assert_eq!(metrics::counter_value("cluster.sync.all_gathers"), stats.all_gathers);
    // Two rounds ran, so the runtime saw at least every plan-level skip.
    assert!(
        stats.gathers_skipped >= acc.gathers_skipped as u64,
        "measured skips below plan: {stats:?} vs {acc:?}"
    );

    // The JSON snapshot carries the same numbers.
    let snap = metrics::snapshot();
    let bytes = snap.get("cluster.sync.bytes").and_then(Json::as_f64).expect("snapshot key");
    assert_eq!(bytes as u64, stats.sync_bytes);
    assert_eq!(snap.get("cluster.world").and_then(Json::as_f64), Some(2.0));
    metrics::reset();
}

/// The mobilenet-sized variant of the pinning test — slow, run with
/// `cargo test -- --ignored` when touching the sync or metrics paths.
#[test]
#[ignore]
fn mobilenet_cluster_metrics_match_ground_truth() {
    let _l = obs_lock();
    let g = xenos::graph::models::mobilenet();
    let d = presets::tms320c6678();
    let driver = ClusterDriver::local(
        Arc::new(g.clone()),
        &d,
        2,
        PartitionScheme::Mix,
        SyncMode::Ring,
        2,
    )
    .expect("cluster spins up");
    let inputs = synthetic_inputs(&g, 23);
    driver.infer(&inputs).expect("inference");
    metrics::reset();
    driver.publish_metrics();
    let acc = driver.plan().accounting(&g);
    let stats = driver.sync_stats().expect("local cluster stats");
    assert_eq!(
        metrics::counter_value("cluster.plan.gathers_skipped"),
        acc.gathers_skipped as u64
    );
    assert_eq!(metrics::counter_value("cluster.sync.bytes"), stats.sync_bytes);
    metrics::reset();
}

#[test]
fn recorder_toggle_is_bit_exact() {
    let _l = obs_lock();
    let g = small_cnn();
    let inputs = synthetic_inputs(&g, 31);
    let ga = Arc::new(g.clone());
    let d = presets::tms320c6678();
    let engines = vec![
        Engine::interp(ga.clone()),
        Engine::par_interp(ga.clone(), &d, 2),
        Engine::cluster(
            ClusterDriver::local(ga.clone(), &d, 2, PartitionScheme::Mix, SyncMode::Ring, 1)
                .expect("cluster spins up"),
        ),
    ];
    for e in &engines {
        trace::set_enabled(false);
        trace::clear();
        let off = e.infer(&inputs).expect("untraced inference");
        assert!(trace::drain().is_empty(), "{}: disabled recorder captured spans", e.name());
        trace::set_enabled(true);
        let on = e.infer(&inputs).expect("traced inference");
        trace::set_enabled(false);
        assert!(!trace::drain().is_empty(), "{}: enabled recorder captured nothing", e.name());
        trace::clear();
        assert_eq!(off.outputs.len(), on.outputs.len());
        for (a, b) in off.outputs.iter().zip(&on.outputs) {
            assert_eq!(a.data, b.data, "{}: tracing changed the numerics", e.name());
        }
    }
}

/// The plan-vs-actual report, pinned on hand-authored spans: measured
/// time is span-sum / iters / ranks-that-computed-the-node, unknown span
/// names join no node (but still land in the per-rank split), and the
/// per-rank compute/wait/halo fractions reconcile exactly.
#[test]
fn drift_report_reconciles_fabricated_spans() {
    let g = small_cnn();
    let d = presets::tms320c6678();
    let ev = |name: &str, cat: trace::Cat, dur_us: u64, lane: u32| trace::SpanEvent {
        name: name.to_string(),
        cat,
        ts_us: 0,
        dur_us,
        lane,
        tid: 1,
        bytes: 0,
    };
    // Two inferences: c1 ran 4ms total on one rank, c2 2ms on each of two
    // ranks; one span names no node; rank 1 waited, rank 0 exchanged halos.
    let events = vec![
        ev("c1", trace::Cat::Compute, 4_000, 0),
        ev("c2", trace::Cat::Compute, 2_000, 0),
        ev("c2", trace::Cat::Compute, 2_000, 1),
        ev("not_a_node", trace::Cat::Compute, 6_000, 0),
        ev("allgather", trace::Cat::Wait, 1_000, 1),
        ev("halo", trace::Cat::Halo, 500, 0),
    ];
    let r = DriftReport::build(&g, &d, None, &events, 2, 3);
    assert_eq!(r.iters, 2);

    let node = |name: &str| r.nodes.iter().find(|n| n.name == name).expect(name);
    let approx = |a: f64, b: f64| (a - b).abs() < 1e-12;
    // c1: 4000us / 1e6 / 2 iters / 1 rank.
    assert!(approx(node("c1").measured_s, 0.002), "{:?}", node("c1"));
    // c2: (2000+2000)us / 1e6 / 2 iters / 2 ranks.
    assert!(approx(node("c2").measured_s, 0.001), "{:?}", node("c2"));
    // Un-measured node: zero measured, zero ratio, positive prediction.
    assert_eq!(node("fc").measured_s, 0.0);
    assert_eq!(node("fc").ratio, 0.0);
    assert!(node("fc").predicted_s > 0.0);
    // Every row carries the single-device scheme and a profile join key.
    assert!(r.nodes.iter().all(|n| n.scheme == "serial"), "{:?}", r.nodes);
    let c1_node = g.nodes.iter().find(|n| n.name == "c1").unwrap();
    assert_eq!(node("c1").signature, op_signature(c1_node));
    assert!(approx(node("c1").ratio, node("c1").measured_s / node("c1").predicted_s));
    // Totals: only spans that joined a node count as measured.
    assert!(approx(r.measured_total_s, 0.003), "{}", r.measured_total_s);
    assert!(r.predicted_total_s > 0.0);
    assert!(approx(r.overall_ratio(), r.measured_total_s / r.predicted_total_s));

    // Per-rank split covers *all* spans, joined or not.
    assert_eq!(r.per_rank.len(), 2);
    let r0 = &r.per_rank[0];
    let r1 = &r.per_rank[1];
    assert!(approx(r0.compute_s, 0.006), "{r0:?}"); // (4000+2000+6000)/1e6/2
    assert!(approx(r0.halo_s, 0.00025), "{r0:?}");
    assert_eq!(r0.wait_s, 0.0);
    assert!(approx(r1.compute_s, 0.001), "{r1:?}");
    assert!(approx(r1.wait_s, 0.0005), "{r1:?}");
    let (c, w, h) = r0.fractions();
    assert!(approx(c + w + h, 1.0));

    // Offenders: exactly the measured nodes, worst absolute drift first.
    assert_eq!(r.offenders.len(), 2, "{:?}", r.offenders);
    assert!(r.offenders.iter().all(|o| o == "c1" || o == "c2"), "{:?}", r.offenders);

    // The report document round-trips and the renderer names the scheme.
    let doc = Json::parse(&r.to_json().to_pretty()).expect("report JSON parses");
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some("xenos-drift-v1"));
    assert_eq!(doc.get("iters").and_then(Json::as_f64), Some(2.0));
    assert!(r.render(3).contains("serial"), "{}", r.render(3));
}

/// The analyze pipeline end-to-end against the live recorder: a traced
/// interpreter run produces one compute span per node, and the report's
/// measured totals reconcile with the raw spans it was built from.
#[test]
fn drift_report_reconciles_with_the_live_recorder() {
    let _l = obs_lock();
    let g = small_cnn();
    let d = presets::tms320c6678();
    let inputs = synthetic_inputs(&g, 41);
    let engine = Engine::interp(Arc::new(g.clone()));
    trace::clear();
    trace::set_enabled(true);
    engine.infer(&inputs).expect("traced inference");
    trace::set_enabled(false);
    let events = trace::drain();
    trace::clear();

    let r = DriftReport::build(&g, &d, None, &events, 1, 5);
    // Sub-µs ops can legitimately record a 0µs span; the convolutions
    // cannot — they must carry measured time.
    for name in ["c1", "c2"] {
        let n = r.nodes.iter().find(|n| n.name == name).expect(name);
        assert!(n.measured_s > 0.0, "node {name} has no measured time");
    }
    let span_total: f64 = events
        .iter()
        .filter(|e| e.cat == trace::Cat::Compute)
        .map(|e| e.dur_us as f64 / 1e6)
        .sum();
    assert!(
        (r.measured_total_s - span_total).abs() < 1e-9,
        "report total {} != span total {span_total}",
        r.measured_total_s
    );
    // The same spans feed the profile store: every node contributes.
    let mut db = ProfileDb::default();
    let matched = db.merge_spans(&g, &events, 1);
    assert_eq!(matched, r.nodes.len(), "profile store and report join the same spans");
}

/// Measured profiles steer the cluster planner: under `Mix`, an op the
/// profile knows to be expensive gets sharded, and the same op measured
/// as nearly free stays replicated (sync traffic would dominate) — in
/// both directions overriding whatever the analytic model would do.
#[test]
fn measured_costs_steer_the_mix_plan() {
    let g = small_cnn();
    let d = presets::tms320c6678();
    let c2 = g.nodes.iter().find(|n| n.name == "c2").expect("c2 node");
    let plan = |src: &CostSource| {
        plan_cluster_src(&g, &d, 3, PartitionScheme::Mix, SyncMode::Ring, Precision::F32, true, src)
    };

    let mut slow = ProfileDb::default();
    slow.record(&op_signature(c2), 1000.0, 10); // measured mean: 100s
    let sharded = plan(&CostSource::Measured(slow));
    assert_ne!(
        sharded.scheme_label(c2.id),
        "replicated",
        "a 100s op must shard: compute/p beats any sync bill"
    );

    let mut fast = ProfileDb::default();
    fast.record(&op_signature(c2), 1e-8, 10); // measured mean: 1ns
    let replicated = plan(&CostSource::Measured(fast));
    assert_eq!(
        replicated.scheme_label(c2.id),
        "replicated",
        "a 1ns op must not shard: sync traffic dominates"
    );

    // The explicit analytic source is exactly the historical planner.
    let a = plan(&CostSource::Analytic);
    let b =
        plan_cluster_opts(&g, &d, 3, PartitionScheme::Mix, SyncMode::Ring, Precision::F32, true);
    let labels = |p: &xenos::dist::exec::ClusterPlan| {
        g.nodes.iter().map(|n| p.scheme_label(n.id)).collect::<Vec<_>>()
    };
    assert_eq!(labels(&a), labels(&b));
}

#[test]
fn committed_bench_artifacts_are_schema_valid() {
    for name in ["BENCH_kernels.json", "BENCH_serve.json"] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
        let doc = Json::parse(&text).unwrap_or_else(|e| panic!("{name} does not parse: {e:#}"));
        let entries =
            validate_bench_json(&doc).unwrap_or_else(|e| panic!("{name} is invalid: {e:#}"));
        assert!(!entries.is_empty(), "{name} has no entries");
    }
}
