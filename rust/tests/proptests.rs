//! Property-based tests over the coordinator/optimizer invariants, using
//! the in-crate `testkit` mini-framework (proptest is not vendored).

use xenos::graph::{ConvAttrs, GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::opt::dos;
use xenos::testkit::{forall, FnGen};
use xenos::util::rng::Rng;

/// Random conv layer dims: (in_c, out_c, k, hw, stride).
fn conv_gen() -> FnGen<(usize, usize, usize, usize, usize), impl Fn(&mut Rng) -> (usize, usize, usize, usize, usize)>
{
    FnGen(|rng: &mut Rng| {
        let in_c = 1 << rng.usize_range(0, 9); // 1..512
        let out_c = 1 << rng.usize_range(0, 10); // 1..1024
        let k = [1, 3, 5, 7][rng.usize_below(4)];
        let hw = rng.usize_range(k, 64);
        let stride = rng.usize_range(1, 2);
        (in_c, out_c, k, hw, stride)
    })
}

#[test]
fn dos_plan_invariants_hold_for_random_convs() {
    for device in [presets::tms320c6678(), presets::zcu102()] {
        forall(42, 300, &conv_gen(), |(in_c, out_c, k, hw, stride)| {
            let mut b = GraphBuilder::new("prop");
            let x = b.input("x", Shape::nchw(1, in_c, hw, hw));
            let a = ConvAttrs { in_c, out_c, kh: k, kw: k, stride, pad: k / 2, groups: 1 };
            let c = b.conv_attrs("c", x, a);
            b.output(c);
            let g = b.finish();
            let p = dos::plan_node_dos(&g, g.node(c), &device, false);

            // Invariant 1: never oversubscribe the device.
            assert!(p.units >= 1 && p.units <= device.dsp_units, "units {}", p.units);
            // Invariant 2: partition ways multiply to the unit count.
            assert_eq!(p.ways(), p.units);
            // Invariant 3: balance is a valid efficiency.
            assert!(p.balance > 0.0 && p.balance <= 1.0, "balance {}", p.balance);
            // Invariant 4: after splitting, the chunk fits the DMA budget.
            if let Some(s) = p.param_split {
                assert!(s.chunks >= 1);
                assert!(
                    s.chunk_bytes <= device.l2.capacity / 2,
                    "chunk {} > budget",
                    s.chunk_bytes
                );
                // Invariant 5: chunks cover the per-unit parameter share
                // (no dropped weights).
                let per_unit_oc = xenos::util::ceil_div(out_c, p.ways_outc());
                let slice_bytes = (in_c * k * k * 4) as u64;
                assert!(
                    s.chunks as u64 * s.chunk_bytes + slice_bytes
                        > per_unit_oc as u64 * slice_bytes / if s.needs_reduction { in_c as u64 } else { 1 },
                    "chunks must cover the weight share"
                );
                // Invariant 6: K-splits never need a reduction.
                if s.dim == xenos::opt::SplitDim::K {
                    assert!(!s.needs_reduction);
                }
            }
            // Invariant 7: fit flag is honest.
            if p.params_fit_l2 {
                let ws = p
                    .param_split
                    .map(|s| s.chunk_bytes)
                    .unwrap_or_else(|| {
                        (g.node(c).param_bytes()) / p.units.max(1) as u64
                    });
                assert!(ws <= device.l2.capacity, "resident set {} > L2", ws);
            }
        });
    }
}

#[test]
fn vanilla_plans_never_split() {
    forall(43, 200, &conv_gen(), |(in_c, out_c, k, hw, stride)| {
        let mut b = GraphBuilder::new("prop");
        let x = b.input("x", Shape::nchw(1, in_c, hw, hw));
        let a = ConvAttrs { in_c, out_c, kh: k, kw: k, stride, pad: k / 2, groups: 1 };
        let c = b.conv_attrs("c", x, a);
        b.output(c);
        let g = b.finish();
        let p = dos::plan_node_vanilla(g.node(c), &presets::tms320c6678());
        assert!(p.param_split.is_none());
        assert!(!p.dma_overlap);
    });
}

#[test]
fn ring_allreduce_matches_sum_for_random_sizes() {
    let gen = FnGen(|rng: &mut Rng| {
        let p = rng.usize_range(2, 6);
        let n = rng.usize_range(1, 500);
        let inputs: Vec<Vec<f32>> = (0..p).map(|_| rng.vec_uniform(n)).collect();
        inputs
    });
    forall(44, 40, &gen, |inputs| {
        let n = inputs[0].len();
        let mut expect = vec![0.0f32; n];
        for v in &inputs {
            for (e, x) in expect.iter_mut().zip(v) {
                *e += x;
            }
        }
        for r in xenos::dist::ring::ring_allreduce_exec(inputs) {
            for (a, b) in r.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    });
}

#[test]
fn coordinator_serves_every_request_exactly_once() {
    use std::sync::Arc;
    use xenos::runtime::Engine;
    use xenos::serve::{BatcherConfig, Coordinator, ServeConfig};

    let gen = FnGen(|rng: &mut Rng| {
        (
            rng.usize_range(1, 4),   // workers
            rng.usize_range(1, 16),  // max_batch
            rng.usize_range(1, 120), // requests
            rng.next_u64(),          // seed
        )
    });
    let graph = Arc::new({
        let mut b = GraphBuilder::new("prop_serve");
        let x = b.input("x", Shape::nchw(1, 2, 4, 4));
        let r = b.relu("r", x);
        b.output(r);
        b.finish()
    });
    forall(45, 25, &gen, |(workers, max_batch, n, seed)| {
        let g = graph.clone();
        let report = Coordinator::new(ServeConfig {
            workers,
            batcher: BatcherConfig {
                max_batch,
                max_wait: std::time::Duration::from_micros(300),
            },
            ..Default::default()
        })
        .run(
            move |_| Ok(Engine::interp(g.clone())),
            xenos::serve::coordinator::synthetic_requests(
                vec![Shape::nchw(1, 2, 4, 4)],
                n,
                0.0,
                seed,
            ),
        )
        .expect("serve");
        // Exactly-once, id-complete, batch cap respected.
        assert_eq!(report.served, n);
        let ids: Vec<u64> = report.responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
        assert!(report.batch_size.max <= max_batch as f64);
        // Latency always covers execution.
        for r in &report.responses {
            assert!(r.latency_s + 1e-9 >= 0.0 && r.exec_s >= 0.0);
        }
    });
}

#[test]
fn layout_addressing_is_bijective_for_random_fms() {
    use xenos::graph::DataLayout;
    use xenos::sim::cache::fm_addr;
    let gen = FnGen(|rng: &mut Rng| {
        let c = rng.usize_range(1, 16);
        let ph = [1usize, 2, 4][rng.usize_below(3)];
        let h = ph * rng.usize_range(1, 8);
        let w = ph * rng.usize_range(1, 8);
        (c, h, w, ph)
    });
    forall(46, 150, &gen, |(c, h, w, ph)| {
        for layout in [
            DataLayout::Chw,
            DataLayout::Hwc,
            DataLayout::Linked { ph: ph as u8, pw: ph as u8 },
        ] {
            let mut seen = std::collections::HashSet::new();
            for ch in 0..c {
                for y in 0..h {
                    for x in 0..w {
                        assert!(
                            seen.insert(fm_addr(layout, ch, y, x, c, h, w)),
                            "collision in {layout:?} at ({ch},{y},{x})"
                        );
                    }
                }
            }
            assert_eq!(seen.len(), c * h * w);
        }
    });
}

#[test]
fn slice_concat_roundtrip_random() {
    use xenos::ops::{shape_ops, Tensor};
    let gen = FnGen(|rng: &mut Rng| {
        let c = rng.usize_range(2, 24);
        let h = rng.usize_range(1, 8);
        let w = rng.usize_range(1, 8);
        let cut = rng.usize_range(1, c - 1);
        let data = rng.vec_uniform(c * h * w);
        (c, h, w, cut, data)
    });
    forall(47, 200, &gen, |(c, h, w, cut, data)| {
        let t = Tensor::fm(1, c, h, w, data);
        let lo = shape_ops::slice_c(&t, 0, cut);
        let hi = shape_ops::slice_c(&t, cut, c);
        let back = shape_ops::concat_c(&[&lo, &hi]);
        assert_eq!(back.data, t.data);
    });
}

#[test]
fn ingest_requests_round_trip_for_arbitrary_payloads() {
    use xenos::ops::Tensor;
    use xenos::serve::ingest::{decode_request, encode_request, InferRequest};

    // Arbitrary well-formed requests: random id/model/deadline plus 0-3
    // tensors of rank 1, 2, or 4 (rank-4 reconstructs as a feature map on
    // decode, so generate it as one).
    let gen = FnGen(|rng: &mut Rng| {
        let id = rng.next_u64();
        let model: String = (0..rng.usize_range(0, 12))
            .map(|_| (b'a' + (rng.usize_below(26) as u8)) as char)
            .collect();
        let deadline_ms = rng.next_u64() as u32;
        let tensors: Vec<Tensor> = (0..rng.usize_below(4))
            .map(|_| match rng.usize_below(3) {
                0 => {
                    let n = rng.usize_range(1, 16);
                    Tensor::new(
                        xenos::graph::TensorDesc::plain(Shape::new(vec![n])),
                        rng.vec_uniform(n),
                    )
                }
                1 => {
                    let r = rng.usize_range(1, 5);
                    let c = rng.usize_range(1, 5);
                    Tensor::mat(r, c, rng.vec_uniform(r * c))
                }
                _ => {
                    let c = rng.usize_range(1, 4);
                    let h = rng.usize_range(1, 6);
                    let w = rng.usize_range(1, 6);
                    Tensor::fm(1, c, h, w, rng.vec_uniform(c * h * w))
                }
            })
            .collect();
        InferRequest { id, model, deadline_ms, inputs: tensors }
    });
    forall(49, 200, &gen, |req| {
        let back = decode_request(&encode_request(&req)).expect("round trip");
        assert_eq!(back, req);
    });
}

#[test]
fn ingest_decoders_never_panic_on_junk() {
    use xenos::ops::Tensor;
    use xenos::serve::ingest::{
        decode_busy, decode_error, decode_output, decode_request, encode_request, InferRequest,
    };

    // Arbitrary byte junk, plus truncated/bit-flipped valid payloads —
    // the decoders must return a typed error (or a valid decode), never
    // panic and never allocate from a hostile length claim.
    let gen = FnGen(|rng: &mut Rng| {
        let junk: Vec<u8> = (0..rng.usize_range(0, 96)).map(|_| rng.next_u64() as u8).collect();
        let cut = rng.usize_below(64);
        let flip_at = rng.usize_below(64);
        let flip_bit = rng.usize_below(8) as u8;
        (junk, cut, flip_at, flip_bit)
    });
    let valid = encode_request(&InferRequest {
        id: 5,
        model: "m".into(),
        deadline_ms: 10,
        inputs: vec![Tensor::fm(1, 2, 3, 3, (0..18).map(|v| v as f32).collect())],
    });
    forall(50, 400, &gen, |(junk, cut, flip_at, flip_bit)| {
        let _ = decode_request(&junk);
        let _ = decode_output(&junk);
        let _ = decode_error(&junk);
        let _ = decode_busy(&junk);

        let truncated = &valid[..cut.min(valid.len())];
        let _ = decode_request(truncated);

        let mut flipped = valid.clone();
        let at = flip_at % flipped.len();
        flipped[at] ^= 1 << flip_bit;
        let _ = decode_request(&flipped);
    });
}

#[test]
fn linking_preserves_semantics_on_random_chains() {
    use xenos::ops::Interpreter;
    // Random 3-5 layer conv/pool/activation chains.
    let gen = FnGen(|rng: &mut Rng| {
        let layers = rng.usize_range(2, 5);
        let ops: Vec<usize> = (0..layers).map(|_| rng.usize_below(4)).collect();
        let c0 = 1 << rng.usize_range(1, 4);
        let seed = rng.next_u64();
        (ops, c0, seed)
    });
    forall(48, 60, &gen, |(ops, c0, seed)| {
        let mut b = GraphBuilder::new("chain");
        let mut cur = b.input("x", Shape::nchw(1, c0, 16, 16));
        for (i, op) in ops.iter().enumerate() {
            let d = b.desc(cur).clone();
            cur = match op {
                0 => b.conv_bn_relu(&format!("cbr{i}"), cur, d.shape.c() * 2, 1, 1, 0),
                1 => b.dw_bn_relu(&format!("dw{i}"), cur, 3, 1, 1),
                2 if d.shape.h() >= 4 => b.avgpool(&format!("p{i}"), cur, 2, 2),
                _ => b.relu(&format!("r{i}"), cur),
            };
        }
        b.output(cur);
        let g = b.finish();
        let (fused, _) = xenos::opt::fusion::fuse_cbr(&g);
        let linked = xenos::opt::linking::link(&fused);
        let a = Interpreter::new(&g).run_synthetic(seed);
        let c = Interpreter::new(&linked.graph).run_synthetic(seed);
        assert_eq!(a[0].data, c[0].data);
    });
}
