//! Fault-tolerance acceptance suite for the d-Xenos cluster runtime:
//! scripted failures (killed ranks, truncated frames, stalled peers)
//! injected into a live local cluster must surface as typed
//! [`xenos::dist::exec::TransportError`]s — never panics — and the
//! [`ClusterDriver`] must recover by re-planning over the survivors and
//! retrying the round. Because sharded kernels share the serial code
//! paths, the recovered output is **bit-identical** to the single-device
//! reference, so every test here is a differential test: inject the
//! fault, then assert exact equality against the `Interpreter` (f32) or
//! `QuantEngine` (INT8).

use std::sync::Arc;
use std::time::Duration;

use xenos::dist::exec::{
    ClusterDriver, ClusterOptions, Fault, FaultScript, StragglerOptions, StragglerTracker,
};
use xenos::dist::{PartitionScheme, SyncMode};
use xenos::graph::{Graph, GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::ops::interp::synthetic_inputs;
use xenos::ops::params::ParamStore;
use xenos::ops::{Interpreter, Tensor};
use xenos::quant::{CalibTable, QuantEngine};

/// Small CNN with enough layers that every rank performs many transport
/// ops per round — scripted fault indices land mid-inference.
fn fault_cnn() -> Graph {
    let mut b = GraphBuilder::new("fault_cnn");
    let x = b.input("x", Shape::nchw(1, 4, 12, 12));
    let c1 = b.conv_bn_relu("c1", x, 16, 3, 1, 1);
    let dw = b.dw_bn_relu("dw", c1, 3, 1, 1);
    let pw = b.conv_bn_relu("pw", dw, 32, 1, 1, 0);
    let mp = b.maxpool("mp", pw, 2, 2);
    let c2 = b.conv("c2", mp, 16, 3, 1, 1);
    let gp = b.global_pool("gp", c2);
    let fc = b.fc("fc", gp, 10);
    let sm = b.softmax("sm", fc);
    b.output(sm);
    b.finish()
}

fn serial_reference(g: &Graph, seed: u64) -> (Vec<Tensor>, Vec<Tensor>) {
    let inputs = synthetic_inputs(g, seed);
    let want = Interpreter::new(g).run(&inputs);
    (inputs, want)
}

fn assert_outputs_identical(want: &[Tensor], got: &[Tensor], what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: output arity");
    for (a, b) in want.iter().zip(got) {
        assert_eq!(a.shape(), b.shape(), "{what}: shape");
        assert_eq!(a.data, b.data, "{what}: diverged from the serial reference");
    }
}

fn faulty_opts(fault: FaultScript) -> ClusterOptions {
    ClusterOptions {
        recv_timeout: Duration::from_millis(500),
        infer_timeout: Duration::from_secs(30),
        fault: Some(fault),
        ..ClusterOptions::default()
    }
}

fn faulty_driver(
    g: &Graph,
    p: usize,
    scheme: PartitionScheme,
    sync: SyncMode,
    fault: FaultScript,
) -> ClusterDriver {
    let d = presets::tms320c6678();
    ClusterDriver::local_with(
        Arc::new(g.clone()),
        &d,
        p,
        scheme,
        sync,
        faulty_opts(fault),
        None,
    )
    .expect("cluster spins up")
}

/// A rank killed mid-collective on a 3-way cluster: the driver must
/// detect the death, re-plan over the two survivors, retry, and return
/// the bit-exact result.
#[test]
fn kill_mid_inference_replans_and_matches_serial() {
    let g = fault_cnn();
    let (inputs, want) = serial_reference(&g, 70);
    let driver =
        faulty_driver(&g, 3, PartitionScheme::OutC, SyncMode::Ring, FaultScript::kill(2, 5));
    let got = driver.infer(&inputs).expect("recovered inference");
    assert_outputs_identical(&want, &got, "kill p=3");
    assert_eq!(driver.world(), 2, "one rank dropped");
    let f = driver.fault_stats();
    assert!(f.failures >= 1, "failure detected: {f:?}");
    assert!(f.replans >= 1, "survivor re-plan ran: {f:?}");
    assert!(f.retries >= 1, "round retried: {f:?}");
    assert_eq!(f.fallbacks, 0, "no single-device fallback: {f:?}");
    // Recovered cluster stays serviceable for subsequent rounds.
    let again = driver.infer(&inputs).expect("post-recovery inference");
    assert_outputs_identical(&want, &again, "kill p=3 second round");
}

/// Killing rank 0 (the output-owning rank) must recover identically —
/// survivor ranks are renumbered by the re-plan.
#[test]
fn kill_rank_zero_replans_and_matches_serial() {
    let g = fault_cnn();
    let (inputs, want) = serial_reference(&g, 71);
    let driver =
        faulty_driver(&g, 3, PartitionScheme::Mix, SyncMode::Ring, FaultScript::kill(0, 4));
    let got = driver.infer(&inputs).expect("recovered inference");
    assert_outputs_identical(&want, &got, "kill rank 0");
    assert_eq!(driver.world(), 2, "one rank dropped");
    assert!(driver.fault_stats().replans >= 1);
}

/// With only two ranks, losing one leaves no cluster to re-plan: the
/// driver must fall back to the single-device engine and still answer
/// bit-exactly.
#[test]
fn kill_with_two_ranks_falls_back_to_single_device() {
    let g = fault_cnn();
    let (inputs, want) = serial_reference(&g, 72);
    let driver =
        faulty_driver(&g, 2, PartitionScheme::OutC, SyncMode::Ring, FaultScript::kill(1, 3));
    let got = driver.infer(&inputs).expect("fallback inference");
    assert_outputs_identical(&want, &got, "fallback p=2");
    assert_eq!(driver.world(), 1, "single survivor");
    assert!(driver.label().starts_with("cluster-fallback"), "label: {}", driver.label());
    let f = driver.fault_stats();
    assert_eq!(f.fallbacks, 1, "fell back exactly once: {f:?}");
    assert!(f.failures >= 1 && f.retries >= 1, "{f:?}");
}

/// A truncated frame mid-collective is a protocol error, not a panic:
/// the driver drops an end of the corrupt link and the retried round —
/// on a clean rebuilt mesh — is bit-exact.
#[test]
fn truncated_frame_recovers_bit_exact() {
    let g = fault_cnn();
    let (inputs, want) = serial_reference(&g, 73);
    // Ring ops alternate send/recv; scripting two consecutive indices
    // guarantees one lands on a send (truncation is a no-op on a recv).
    let fault = FaultScript::truncate(1, 4).and(1, Fault::Truncate { at_op: 5 });
    let driver = faulty_driver(&g, 3, PartitionScheme::OutC, SyncMode::Ring, fault);
    let got = driver.infer(&inputs).expect("recovered inference");
    assert_outputs_identical(&want, &got, "truncate p=3");
    assert_eq!(driver.world(), 2, "one end of the corrupt link dropped");
    assert!(driver.fault_stats().replans >= 1);
}

/// A slow rank inside the recv deadline is not a failure: the round
/// completes on the original cluster with no re-planning.
#[test]
fn slow_rank_within_deadline_is_not_a_failure() {
    let g = fault_cnn();
    let (inputs, want) = serial_reference(&g, 74);
    let fault = FaultScript::delay(1, 2, Duration::from_millis(50));
    let driver = faulty_driver(&g, 3, PartitionScheme::OutC, SyncMode::Ring, fault);
    let got = driver.infer(&inputs).expect("slow but healthy inference");
    assert_outputs_identical(&want, &got, "tolerated delay");
    assert_eq!(driver.world(), 3, "no rank dropped");
    assert_eq!(driver.fault_stats(), Default::default(), "no counters tripped");
}

/// A rank stalled past the recv deadline is indistinguishable from a
/// dead one: peers time out, the driver drops it and recovers.
#[test]
fn stalled_rank_past_deadline_is_dropped() {
    let g = fault_cnn();
    let (inputs, want) = serial_reference(&g, 75);
    let fault = FaultScript::delay(1, 2, Duration::from_millis(1500));
    let d = presets::tms320c6678();
    let opts = ClusterOptions {
        recv_timeout: Duration::from_millis(150),
        infer_timeout: Duration::from_secs(30),
        fault: Some(fault),
        ..ClusterOptions::default()
    };
    let driver = ClusterDriver::local_with(
        Arc::new(g.clone()),
        &d,
        3,
        PartitionScheme::OutC,
        SyncMode::Ring,
        opts,
        None,
    )
    .expect("cluster spins up");
    let got = driver.infer(&inputs).expect("recovered inference");
    assert_outputs_identical(&want, &got, "deadline-dropped rank");
    assert_eq!(driver.world(), 2, "stalled rank dropped");
    let f = driver.fault_stats();
    assert!(f.failures >= 1 && f.replans >= 1, "{f:?}");
}

/// INT8 path: a kill mid-inference on a quantized cluster re-plans and
/// the recovered output is bit-identical to the serial `QuantEngine` —
/// re-planning re-extracts shard weights and quantized row offsets, so
/// integer accumulation is unchanged.
#[test]
fn quantized_kill_replans_bit_exact() {
    let g = fault_cnn();
    let params = ParamStore::for_graph(&g);
    let calib = CalibTable::synthetic(&g, &params, 4, 1000);
    let ga = Arc::new(g.clone());
    let inputs = synthetic_inputs(&g, 76);
    let want = QuantEngine::new(ga.clone(), &calib, 1).expect("quant engine").run(&inputs);
    let d = presets::tms320c6678();
    let driver = ClusterDriver::local_with(
        ga,
        &d,
        3,
        PartitionScheme::OutC,
        SyncMode::Ring,
        faulty_opts(FaultScript::kill(2, 5)),
        Some(&calib),
    )
    .expect("quant cluster spins up");
    let got = driver.infer(&inputs).expect("recovered quantized inference");
    assert_outputs_identical(&want, &got, "quantized kill p=3");
    assert_eq!(driver.world(), 2, "one rank dropped");
    assert!(driver.fault_stats().replans >= 1);
}

/// Multiple scripted faults across successive rounds: kill one rank on
/// the first round (3 -> 2), then — because rebuilt meshes get clean
/// transports — the second round runs faultlessly on the survivors.
#[test]
fn successive_rounds_after_recovery_stay_exact() {
    let g = fault_cnn();
    let (inputs, want) = serial_reference(&g, 77);
    let driver =
        faulty_driver(&g, 3, PartitionScheme::InH, SyncMode::Ring, FaultScript::kill(1, 6));
    for round in 0..3 {
        let got = driver.infer(&inputs).expect("inference");
        assert_outputs_identical(&want, &got, &format!("round {round}"));
    }
    let f = driver.fault_stats();
    assert_eq!(f.replans, 1, "fault observed exactly once: {f:?}");
    assert_eq!(driver.world(), 2);
}

/// A culprit-free failure — the driver's round deadline lapses while
/// every rank is still blocked inside its own (longer) recv deadline —
/// must surface as an error for that round but *not* poison the
/// cluster: the driver rebuilds the mesh at the same world size and the
/// next round succeeds bit-exactly.
#[test]
fn driver_deadline_lapse_does_not_brick_the_cluster() {
    let g = fault_cnn();
    let (inputs, want) = serial_reference(&g, 78);
    // Rank 1 stalls 1.2s mid-round; the per-recv deadline (30s) never
    // fires, so no rank can be blamed — only the driver's 200ms round
    // deadline trips.
    let fault = FaultScript::delay(1, 2, Duration::from_millis(1200));
    let d = presets::tms320c6678();
    let opts = ClusterOptions {
        recv_timeout: Duration::from_secs(30),
        infer_timeout: Duration::from_millis(200),
        fault: Some(fault),
        ..ClusterOptions::default()
    };
    let driver = ClusterDriver::local_with(
        Arc::new(g.clone()),
        &d,
        3,
        PartitionScheme::OutC,
        SyncMode::Ring,
        opts,
        None,
    )
    .expect("cluster spins up");

    let err = driver.infer(&inputs).expect_err("round deadline must fail this round");
    assert!(err.to_string().contains("no identifiable culprit"), "err: {err:#}");
    assert_eq!(driver.world(), 3, "no rank was blamed or dropped");

    // The rebuilt mesh gets a clean transport (fault scripts only apply
    // to the initial build), so subsequent rounds are exact.
    for round in 0..2 {
        let got = driver.infer(&inputs).expect("post-rebuild inference");
        assert_outputs_identical(&want, &got, &format!("post-rebuild round {round}"));
    }
    let f = driver.fault_stats();
    assert!(f.failures >= 1, "{f:?}");
    assert_eq!(f.fallbacks, 0, "{f:?}");
}

/// The straggler scorer is a pure state machine: a rank past the slowdown
/// threshold builds a streak, fires only after `patience` consecutive
/// rounds, fires once per detection, and a healthy round resets the
/// streak.
#[test]
fn straggler_tracker_fires_after_patience_and_only_once() {
    let opts = StragglerOptions { alpha: 1.0, slowdown: 2.0, patience: 3, reprobe_every: 8 };
    let mut t = StragglerTracker::new(opts, 3);
    assert_eq!(t.observe(&[100, 100, 1000]), None, "streak 1 of 3");
    assert_eq!(t.observe(&[100, 100, 1000]), None, "streak 2 of 3");
    assert_eq!(t.observe(&[100, 100, 1000]), Some(2), "patience reached");
    assert_eq!(t.observe(&[100, 100, 1000]), None, "detection is one-shot");

    let mut t = StragglerTracker::new(opts, 3);
    t.observe(&[100, 100, 1000]);
    t.observe(&[100, 100, 1000]);
    assert_eq!(t.observe(&[100, 100, 100]), None, "healthy round clears the streak");
    assert_eq!(t.observe(&[100, 100, 1000]), None, "streak rebuilds from zero");

    t.reset(2);
    assert_eq!(t.scores(), &[1.0, 1.0], "reset forgets all history");
}

/// EWMA smoothing, worst-offender selection among several qualifying
/// stragglers, and degenerate inputs (world mismatch, tiny clusters,
/// all-idle rounds) that must never name a victim.
#[test]
fn straggler_tracker_smooths_and_picks_the_worst_offender() {
    // alpha 0.5: one 9x round lands at 0.5*9 + 0.5*1 = 5.0.
    let opts = StragglerOptions { alpha: 0.5, slowdown: 2.0, patience: 1, reprobe_every: 8 };
    let mut t = StragglerTracker::new(opts, 3);
    assert_eq!(t.observe(&[100, 100, 900]), Some(2));
    assert!((t.scores()[2] - 5.0).abs() < 1e-9, "EWMA: {:?}", t.scores());

    // Two ranks past the threshold in the same round: the worse score wins.
    let opts = StragglerOptions { alpha: 1.0, slowdown: 2.0, patience: 1, reprobe_every: 8 };
    let mut t = StragglerTracker::new(opts, 5);
    assert_eq!(t.observe(&[100, 100, 100, 600, 900]), Some(4), "worst offender wins");

    // Degenerate rounds are ignored, never scored.
    let mut t = StragglerTracker::new(opts, 3);
    assert_eq!(t.observe(&[100, 100]), None, "world-size mismatch");
    assert_eq!(t.observe(&[0, 0, 0]), None, "all-idle round");
    let mut tiny = StragglerTracker::new(opts, 1);
    assert_eq!(tiny.observe(&[100]), None, "nothing to compare against");
}

/// The tentpole end-to-end: a rank scripted to stall a few ms on *every*
/// transport op is never slow enough to trip a deadline, but its busy
/// time dwarfs its peers' round after round — the driver must demote it
/// proactively (straggler counters move, fault counters do not), keep
/// answering bit-exactly at the reduced world size, and after the probe
/// interval re-admit it (local re-spawns get clean transports), restoring
/// the original world — still bit-exact throughout.
#[test]
fn persistent_straggler_is_demoted_then_readmitted() {
    let g = fault_cnn();
    let (inputs, want) = serial_reference(&g, 79);
    // A persistent straggler: `Fault::Delay` fires only at its exact op
    // index, so chain one entry per index to slow every op of the first
    // rounds (demotion lands long before the script runs out).
    let delay = Duration::from_millis(3);
    let mut fault = FaultScript::delay(2, 0, delay);
    for i in 1..2000u64 {
        fault = fault.and(2, Fault::Delay { at_op: i, delay });
    }
    let opts = ClusterOptions {
        // Deadlines generous enough that the fault path can never fire:
        // any demotion below is provably proactive.
        recv_timeout: Duration::from_secs(10),
        infer_timeout: Duration::from_secs(60),
        fault: Some(fault),
        straggler: Some(StragglerOptions {
            alpha: 1.0,
            slowdown: 3.0,
            patience: 2,
            reprobe_every: 2,
        }),
        ..ClusterOptions::default()
    };
    let d = presets::tms320c6678();
    let driver = ClusterDriver::local_with(
        Arc::new(g.clone()),
        &d,
        3,
        PartitionScheme::OutC,
        SyncMode::Ring,
        opts,
        None,
    )
    .expect("cluster spins up");

    // Phase 1: every round is bit-exact; after `patience` rounds the
    // scripted rank is demoted (world 3 -> 2).
    let mut demoted = false;
    for round in 0..6 {
        let got = driver.infer(&inputs).expect("inference");
        assert_outputs_identical(&want, &got, &format!("round {round}"));
        if driver.world() == 2 {
            demoted = true;
            break;
        }
    }
    let s = driver.straggler_stats();
    assert!(demoted, "straggler never demoted: {s:?}");
    assert!(s.demotions >= 1, "{s:?}");
    assert_eq!(s.demoted, 1, "one member awaiting re-admission: {s:?}");
    // Proactive means the failure path never ran: no deadline tripped, no
    // failure-driven retry, no fallback.
    let f = driver.fault_stats();
    assert_eq!(f.failures, 0, "demotion must beat the deadline: {f:?}");
    assert_eq!(f.retries, 0, "{f:?}");
    assert_eq!(f.fallbacks, 0, "{f:?}");

    // Phase 2: after `reprobe_every` healthy rounds the demoted rank is
    // re-admitted with clean transports and the world is restored.
    let mut readmitted = false;
    for round in 0..8 {
        let got = driver.infer(&inputs).expect("post-demotion inference");
        assert_outputs_identical(&want, &got, &format!("post-demotion round {round}"));
        if driver.world() == 3 {
            readmitted = true;
            break;
        }
    }
    let s = driver.straggler_stats();
    assert!(readmitted, "demoted rank never re-admitted: {s:?}");
    assert!(s.readmissions >= 1, "{s:?}");
    assert_eq!(s.demoted, 0, "ledger drained: {s:?}");

    // The restored 3-rank cluster keeps answering bit-exactly.
    let got = driver.infer(&inputs).expect("post-readmission inference");
    assert_outputs_identical(&want, &got, "post-readmission");
    assert_eq!(driver.world(), 3);
    assert_eq!(driver.fault_stats().failures, 0, "still no deadline trips");
}
