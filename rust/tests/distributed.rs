//! Integration tests for d-Xenos: scheme enumeration, sync-mode contrast,
//! scaling behaviour, and collective correctness at realistic sizes.

use xenos::dist::{
    enumerate_schemes, ps, ring, simulate_dxenos, PartitionScheme, SyncMode,
};
use xenos::graph::models;
use xenos::hw::presets;
use xenos::util::rng::Rng;

#[test]
fn fig11_full_matrix_orderings() {
    // For every Fig-11 model: Ring-Mix >= any other ring scheme, and
    // PS-Mix is worse than Ring-Mix (server bottleneck).
    let d = presets::tms320c6678();
    for name in ["mobilenet", "resnet101", "bert_l"] {
        let g = models::by_name(name).unwrap();
        let ring_mix = simulate_dxenos(&g, &d, 4, PartitionScheme::Mix, SyncMode::Ring);
        let ps_mix = simulate_dxenos(&g, &d, 4, PartitionScheme::Mix, SyncMode::Ps);
        assert!(
            ps_mix.total_s > ring_mix.total_s,
            "{name}: PS {} should exceed Ring {}",
            ps_mix.total_s,
            ring_mix.total_s
        );
        for scheme in [PartitionScheme::OutC, PartitionScheme::InH, PartitionScheme::InW] {
            let r = simulate_dxenos(&g, &d, 4, scheme, SyncMode::Ring);
            assert!(
                ring_mix.total_s <= r.total_s * 1.0001,
                "{name}: Mix {} should beat {scheme:?} {}",
                ring_mix.total_s,
                r.total_s
            );
        }
    }
}

#[test]
fn algorithm1_picks_profiled_best_on_both_sync_modes() {
    let d = presets::tms320c6678();
    let g = models::resnet101();
    for sync in [SyncMode::Ring, SyncMode::Ps] {
        let (best, reports) = enumerate_schemes(&g, &d, 4, sync);
        let tmin = reports.iter().map(|r| r.total_s).fold(f64::INFINITY, f64::min);
        let tbest = reports.iter().find(|r| r.scheme == best).unwrap().total_s;
        assert!((tbest - tmin).abs() < 1e-12, "{sync:?}");
    }
}

#[test]
fn speedup_grows_then_saturates() {
    let d = presets::tms320c6678();
    let g = models::resnet101();
    let mut prev = 0.0;
    for p in [1, 2, 4, 8] {
        let s = simulate_dxenos(&g, &d, p, PartitionScheme::Mix, SyncMode::Ring).speedup();
        assert!(s >= prev * 0.98, "p={p}: speedup {s} regressed from {prev}");
        assert!(s <= p as f64 * 1.05, "p={p}: superlinear {s}");
        prev = s;
    }
}

#[test]
fn collectives_agree_at_parameter_scale() {
    // 1M-element all-reduce (a real ResNet layer's worth of floats).
    let mut rng = Rng::new(9);
    let n = 1 << 20;
    let inputs: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_uniform(n)).collect();
    let ring_out = ring::ring_allreduce_exec(inputs.clone());
    let ps_out = ps::ps_allreduce_exec(inputs);
    for (a, b) in ring_out[0].iter().zip(&ps_out[0]) {
        assert!((a - b).abs() < 1e-3);
    }
    // All workers hold identical results.
    for w in 1..4 {
        assert_eq!(ring_out[0], ring_out[w]);
    }
}

#[test]
fn ring_time_model_consistency() {
    // More data, more time; more latency, more time; monotone in p for
    // fixed data until the bandwidth term saturates.
    let link = presets::tms320c6678().link;
    assert!(
        ring::ring_allreduce_time(4, 2 << 20, &link)
            > ring::ring_allreduce_time(4, 1 << 20, &link)
    );
    let slow = xenos::hw::LinkModel { bandwidth: link.bandwidth, latency: link.latency * 100.0 };
    assert!(
        ring::ring_allreduce_time(4, 1 << 20, &slow)
            > ring::ring_allreduce_time(4, 1 << 20, &link)
    );
}

#[test]
fn bert_prefers_outc_over_spatial_schemes() {
    // Matrices have no spatial dims: inW collapses to serial, so outC must
    // win among single modes — the "no one-size-fits-all" evidence.
    let d = presets::tms320c6678();
    let g = models::bert_l();
    let outc = simulate_dxenos(&g, &d, 4, PartitionScheme::OutC, SyncMode::Ring);
    let inw = simulate_dxenos(&g, &d, 4, PartitionScheme::InW, SyncMode::Ring);
    assert!(
        outc.total_s < inw.total_s,
        "outC {} should beat inW {} for transformers",
        outc.total_s,
        inw.total_s
    );
}

#[test]
fn cnn_prefers_spatial_over_outc() {
    // Convs pay a full activation all-gather under outC but only halo
    // exchanges under inH: the opposite preference from transformers.
    let d = presets::tms320c6678();
    let g = models::mobilenet();
    let outc = simulate_dxenos(&g, &d, 4, PartitionScheme::OutC, SyncMode::Ring);
    let inh = simulate_dxenos(&g, &d, 4, PartitionScheme::InH, SyncMode::Ring);
    assert!(
        inh.total_s < outc.total_s,
        "inH {} should beat outC {} for CNNs",
        inh.total_s,
        outc.total_s
    );
}
