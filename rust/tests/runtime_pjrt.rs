//! Integration tests for the PJRT runtime: load the AOT artifacts produced
//! by `make artifacts` and execute them with real numerics.
//!
//! These tests require `artifacts/` to exist (they are skipped with a clear
//! message otherwise so `cargo test` works from a fresh checkout before
//! `make artifacts`).

use std::sync::Arc;

use xenos::graph::Shape;
use xenos::ops::Tensor;
use xenos::runtime::{Engine, PjrtRuntime};
use xenos::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn smoke_artifact_matches_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load_dir(dir).expect("load artifacts");
    let x = Tensor::mat(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
    let y = Tensor::mat(2, 2, vec![1.0; 4]);
    let out = rt.execute("smoke", &[x, y]).expect("execute smoke");
    // matmul([[1,2],[3,4]], ones) + 2 = [[5,5],[9,9]]
    assert_eq!(out[0].data, vec![5.0, 5.0, 9.0, 9.0]);
}

#[test]
fn linked_and_vanilla_artifacts_agree() {
    // The reproduction's core semantic claim at the artifact level: the
    // dataflow-optimized (Pallas linked kernels) model computes exactly
    // the same function as the vanilla jnp model.
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load_dir(dir).expect("load artifacts");
    let shape = rt.artifact("linked").unwrap().inputs[0].clone();
    let mut rng = Rng::new(99);
    for _seed in 0..4 {
        let x = Tensor::new(
            xenos::graph::TensorDesc::plain(shape.clone()),
            rng.vec_uniform(shape.numel()),
        );
        let a = rt.execute("vanilla", std::slice::from_ref(&x)).unwrap();
        let b = rt.execute("linked", std::slice::from_ref(&x)).unwrap();
        a[0].assert_close(&b[0], 1e-4);
    }
}

#[test]
fn model_output_is_distribution() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load_dir(dir).expect("load artifacts");
    let shape = rt.artifact("linked").unwrap().inputs[0].clone();
    let mut rng = Rng::new(5);
    let x = Tensor::new(
        xenos::graph::TensorDesc::plain(shape.clone()),
        rng.vec_uniform(shape.numel()),
    );
    let out = rt.execute("linked", &[x]).unwrap();
    assert_eq!(out[0].shape(), &Shape::mat(1, 10));
    let sum: f32 = out[0].data.iter().sum();
    assert!((sum - 1.0).abs() < 1e-4, "softmax sums to 1, got {sum}");
}

#[test]
fn pjrt_engine_serves_through_coordinator() {
    // End-to-end: AOT artifact -> PJRT engine -> batcher/router -> metrics.
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load_dir(&dir).expect("probe artifacts");
    let shapes = rt.artifact("linked").unwrap().inputs.clone();
    drop(rt);

    let coord = xenos::serve::Coordinator::new(xenos::serve::ServeConfig {
        workers: 1, // one PJRT client per worker; keep the test light
        batcher: xenos::serve::BatcherConfig::default(),
        ..Default::default()
    });
    let dir2 = dir.clone();
    let report = coord
        .run(
            move |_w| {
                let rt = Arc::new(PjrtRuntime::load_dir(&dir2)?);
                Engine::pjrt(rt, "linked")
            },
            xenos::serve::coordinator::synthetic_requests(shapes, 24, 0.0, 11),
        )
        .expect("serve");
    assert_eq!(report.served, 24);
    assert!(report.throughput > 0.0);
    assert!(report.latency.p50 > 0.0);
}

#[test]
fn wrong_input_shape_is_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load_dir(dir).expect("load artifacts");
    let bad = Tensor::mat(1, 3, vec![0.0; 3]);
    assert!(rt.execute("linked", &[bad]).is_err());
}
