//! Integration tests for the serving coordinator with interpreter engines
//! (the PJRT serving path is covered in `runtime_pjrt.rs`).

use std::sync::Arc;

use xenos::graph::{GraphBuilder, Shape};
use xenos::runtime::Engine;
use xenos::serve::{self, BatcherConfig, Coordinator, PipelineConfig, ServeConfig};

fn small_model() -> Arc<xenos::Graph> {
    let mut b = GraphBuilder::new("serving_model");
    let x = b.input("x", Shape::nchw(1, 3, 16, 16));
    let c1 = b.conv_bn_relu("c1", x, 8, 3, 2, 1);
    let gp = b.global_pool("gp", c1);
    let fc = b.fc("fc", gp, 4);
    let sm = b.softmax("sm", fc);
    b.output(sm);
    Arc::new(b.finish())
}

#[test]
fn coordinator_engine_matrix_agrees_across_workers_and_engines() {
    // workers {1,2} × engine {interp, par(2 threads)} over a zoo model:
    // every request answered exactly once, responses in deterministic
    // (request-id) order, outputs identical across all four cells.
    use xenos::graph::models;
    use xenos::hw::presets;
    let g = Arc::new(models::lstm());
    let d = presets::tms320c6678();
    let shapes: Vec<Shape> =
        g.input_ids().iter().map(|&i| g.node(i).out.shape.clone()).collect();
    let n = 12usize;
    let mut reference: Option<Vec<Vec<f32>>> = None;
    for workers in [1usize, 2] {
        for engine_kind in ["interp", "par"] {
            let cfg = ServeConfig {
                workers,
                engine_threads: 2,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: std::time::Duration::from_micros(200),
                },
                ..Default::default()
            };
            let g2 = g.clone();
            let d2 = d.clone();
            let report = Coordinator::new(cfg)
                .run(
                    move |_| {
                        Ok(match engine_kind {
                            "interp" => Engine::interp(g2.clone()),
                            _ => Engine::par_interp(g2.clone(), &d2, 2),
                        })
                    },
                    serve::coordinator::synthetic_requests(shapes.clone(), n, 0.0, 11),
                )
                .expect("serve");
            assert_eq!(report.served, n, "workers={workers} engine={engine_kind}");
            assert_eq!(report.per_worker.iter().sum::<usize>(), n);
            let ids: Vec<u64> = report.responses.iter().map(|r| r.id).collect();
            assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
            let outs: Vec<Vec<f32>> =
                report.responses.iter().map(|r| r.outputs[0].data.clone()).collect();
            match &reference {
                None => reference = Some(outs),
                Some(want) => {
                    assert_eq!(want, &outs, "workers={workers} engine={engine_kind} diverged")
                }
            }
        }
    }
}

#[test]
fn end_to_end_throughput_and_latency() {
    let g = small_model();
    let report = Coordinator::new(ServeConfig {
        workers: 2,
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: std::time::Duration::from_micros(500),
        },
        ..Default::default()
    })
    .run(
        {
            let g = g.clone();
            move |_| Ok(Engine::interp(g.clone()))
        },
        serve::coordinator::synthetic_requests(
            vec![Shape::nchw(1, 3, 16, 16)],
            100,
            0.0,
            1,
        ),
    )
    .expect("serve");
    assert_eq!(report.served, 100);
    assert!(report.throughput > 10.0, "throughput {}", report.throughput);
    assert!(report.latency.p50 > 0.0 && report.latency.p50 <= report.latency.p99);
    // Every response is a softmax distribution.
    for r in &report.responses {
        let sum: f32 = r.outputs[0].data.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4);
    }
}

#[test]
fn paced_arrivals_do_not_drop_requests() {
    let g = small_model();
    let report = Coordinator::new(ServeConfig::default())
        .run(
            {
                let g = g.clone();
                move |_| Ok(Engine::interp(g.clone()))
            },
            serve::coordinator::synthetic_requests(
                vec![Shape::nchw(1, 3, 16, 16)],
                40,
                500.0,
                2,
            ),
        )
        .expect("serve");
    assert_eq!(report.served, 40);
}

#[test]
fn engine_factory_error_propagates() {
    let report = Coordinator::new(ServeConfig { workers: 1, ..Default::default() }).run(
        |_| anyhow::bail!("boom"),
        serve::coordinator::synthetic_requests(vec![Shape::vec1(4)], 4, 0.0, 3),
    );
    assert!(report.is_err());
}

#[test]
fn pipeline_inference_dominates() {
    // Paper §2.1: "the inference module ... typically takes over 60% of
    // the overall execution time".
    let g = small_model();
    let engine = Engine::interp(g);
    let r = serve::run_pipeline(&engine, PipelineConfig { frames: 32, src_hw: 24, seed: 4 })
        .expect("pipeline");
    assert!(
        r.inference_share() > 0.6,
        "inference share {:.2} should dominate",
        r.inference_share()
    );
}

#[test]
fn idle_tie_breaks_rotate_across_workers() {
    // At low load every dispatch sees all outstanding counts at zero; a
    // fixed lowest-rank tie-break would route every batch to worker 0 and
    // permanently starve the rest. The rotating tie-break must spread
    // batches across the whole pool even when nobody is ever loaded.
    let g = small_model();
    let report = Coordinator::new(ServeConfig {
        workers: 3,
        batcher: BatcherConfig { max_batch: 1, max_wait: std::time::Duration::from_micros(50) },
        ..Default::default()
    })
    .run(
        {
            let g = g.clone();
            move |_| Ok(Engine::interp(g.clone()))
        },
        serve::coordinator::synthetic_requests(vec![Shape::nchw(1, 3, 16, 16)], 24, 200.0, 8),
    )
    .expect("serve");
    assert_eq!(report.served, 24);
    assert_eq!(report.per_worker.iter().sum::<usize>(), 24);
    for (w, &n) in report.per_worker.iter().enumerate() {
        assert!(n >= 2, "worker {w} starved at low load: per_worker={:?}", report.per_worker);
    }
}

#[test]
fn single_worker_preserves_fifo() {
    let g = small_model();
    let report = Coordinator::new(ServeConfig {
        workers: 1,
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: std::time::Duration::from_micros(100),
        },
        ..Default::default()
    })
    .run(
        {
            let g = g.clone();
            move |_| Ok(Engine::interp(g.clone()))
        },
        serve::coordinator::synthetic_requests(
            vec![Shape::nchw(1, 3, 16, 16)],
            32,
            0.0,
            5,
        ),
    )
    .expect("serve");
    // With one worker, completion order == submission order.
    let ids: Vec<u64> = report.responses.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..32).collect::<Vec<_>>());
}
