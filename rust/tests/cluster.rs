//! Differential suite for the d-Xenos cluster runtime (`dist::exec`):
//! distributed inference over `LocalTransport` shard threads must be
//! **element-wise identical** to the single-device serial `Interpreter`
//! for every partition scheme, sync mode and cluster size — sharded
//! kernels share the serial code paths, so the equality is bit-for-bit.
//! The TCP smoke test stands up real `dist-worker` sessions on loopback
//! and round-trips a model through the full wire protocol.

use std::net::TcpListener;
use std::sync::Arc;

use xenos::dist::exec::{
    outc_slices, serve_listener, ClusterDriver, ClusterPlan, LayerScheme, LocalTransport,
    Residency, ShardParams, ShardWorker,
};
use xenos::dist::{PartitionScheme, SyncMode};
use xenos::graph::{models, Graph, GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::ops::interp::synthetic_inputs;
use xenos::ops::params::ParamStore;
use xenos::ops::{Interpreter, Tensor};

fn assert_cluster_matches_serial(
    g: &Graph,
    schemes: &[PartitionScheme],
    sizes: &[usize],
    sync: SyncMode,
    threads: usize,
    seed: u64,
) {
    let d = presets::tms320c6678();
    let inputs = synthetic_inputs(g, seed);
    let want = Interpreter::new(g).run(&inputs);
    let ga = Arc::new(g.clone());
    for &scheme in schemes {
        for &p in sizes {
            let driver = ClusterDriver::local(ga.clone(), &d, p, scheme, sync, threads)
                .expect("cluster spins up");
            let got = driver.infer(&inputs).expect("cluster inference");
            assert_eq!(want.len(), got.len(), "{}: output arity", g.name);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.shape(), b.shape(), "{}: {scheme:?} p={p} shape", g.name);
                assert_eq!(
                    a.data, b.data,
                    "{}: {scheme:?} p={p} sync={sync:?} diverged from serial",
                    g.name
                );
            }
        }
    }
}

/// Small CNN covering dense/pointwise/depthwise convs, both pool kinds,
/// stride-2 downsampling (uneven halos), global pooling, FC and softmax.
fn small_cnn() -> Graph {
    let mut b = GraphBuilder::new("cluster_cnn");
    let x = b.input("x", Shape::nchw(1, 4, 16, 16));
    let c1 = b.conv_bn_relu("c1", x, 16, 3, 1, 1);
    let dw = b.dw_bn_relu("dw", c1, 3, 1, 1);
    let pw = b.conv_bn_relu("pw", dw, 32, 1, 1, 0);
    let mp = b.maxpool("mp", pw, 2, 2);
    let c2 = b.conv("c2", mp, 16, 3, 2, 1);
    let ap = b.avgpool("ap", c2, 2, 2);
    let gp = b.global_pool("gp", ap);
    let fc = b.fc("fc", gp, 10);
    let sm = b.softmax("sm", fc);
    b.output(sm);
    b.finish()
}

/// Branchy graph: residual add, concat, grouped conv, channel shuffle,
/// slice — the shard-alignment edge cases.
fn branchy() -> Graph {
    let mut b = GraphBuilder::new("cluster_branchy");
    let x = b.input("x", Shape::nchw(1, 16, 12, 12));
    let sq = b.conv_bn_relu("squeeze", x, 8, 1, 1, 0);
    let e1 = b.conv_bn_relu("e1", sq, 8, 1, 1, 0);
    let e3 = b.conv_bn_relu("e3", sq, 8, 3, 1, 1);
    let cat = b.concat("cat", &[e1, e3]);
    let g1 = b.gconv("g1", cat, 16, 1, 1, 0, 4);
    let sh = b.channel_shuffle("sh", g1, 4);
    let dw = b.dwconv("dw", sh, 3, 1, 1);
    let add = b.add("add", dw, cat);
    let lo = b.slice_c("lo", add, 0, 8);
    b.output(lo);
    b.finish()
}

/// Upsample decoder (CentreNet-style) for the fractional-halo path.
fn decoder() -> Graph {
    let mut b = GraphBuilder::new("cluster_decoder");
    let x = b.input("x", Shape::nchw(1, 8, 5, 7));
    let u = b.upsample("up", x, 2);
    let c = b.conv_bn_relu("c", u, 4, 3, 1, 1);
    let s = b.sigmoid("sig", c);
    b.output(s);
    b.finish()
}

const ALL_SCHEMES: [PartitionScheme; 4] = [
    PartitionScheme::OutC,
    PartitionScheme::InH,
    PartitionScheme::InW,
    PartitionScheme::Mix,
];

#[test]
fn cnn_matches_serial_all_schemes_ring() {
    assert_cluster_matches_serial(&small_cnn(), &ALL_SCHEMES, &[1, 2, 4], SyncMode::Ring, 1, 60);
}

#[test]
fn cnn_matches_serial_all_schemes_ps() {
    assert_cluster_matches_serial(&small_cnn(), &ALL_SCHEMES, &[2, 3], SyncMode::Ps, 1, 61);
}

#[test]
fn branchy_matches_serial() {
    assert_cluster_matches_serial(&branchy(), &ALL_SCHEMES, &[2, 4], SyncMode::Ring, 1, 62);
}

#[test]
fn decoder_matches_serial_with_odd_extents() {
    // h=5/w=7 shards unevenly at p=2/4; the upsample halo is fractional.
    assert_cluster_matches_serial(&decoder(), &ALL_SCHEMES, &[2, 4], SyncMode::Ring, 1, 63);
}

#[test]
fn lstm_zoo_model_matches_serial() {
    // Matrices end to end: OutC shards the gate FCs, spatial schemes
    // degenerate to replicated — both must stay exact.
    assert_cluster_matches_serial(
        &models::lstm(),
        &[PartitionScheme::OutC, PartitionScheme::Mix],
        &[2, 4],
        SyncMode::Ring,
        1,
        64,
    );
}

#[test]
fn pooled_shard_engine_matches_serial() {
    // threads > 1: each ShardWorker backs its kernels with a local worker
    // pool (the ParInterpreter-style engine) — still bit-exact.
    assert_cluster_matches_serial(
        &small_cnn(),
        &[PartitionScheme::Mix],
        &[2],
        SyncMode::Ring,
        2,
        65,
    );
}

#[test]
fn more_ranks_than_rows_leaves_idle_shards() {
    // p far beyond every extent: most ranks own empty slabs; the cluster
    // must still reassemble the exact result.
    let mut b = GraphBuilder::new("cluster_tiny_rows");
    let x = b.input("x", Shape::nchw(1, 8, 3, 3));
    let c = b.conv_bn_relu("c", x, 4, 3, 1, 1);
    b.output(c);
    let g = b.finish();
    assert_cluster_matches_serial(
        &g,
        &[PartitionScheme::InH, PartitionScheme::OutC],
        &[6],
        SyncMode::Ring,
        1,
        66,
    );
}

#[test]
fn hand_built_cross_axis_plan_matches_serial() {
    // InH feeding InW: the consumer must gather the row-sharded value to
    // full before re-sharding by columns.
    let mut b = GraphBuilder::new("cluster_cross");
    let x = b.input("x", Shape::nchw(1, 4, 10, 10));
    let c1 = b.conv("c1", x, 8, 3, 1, 1);
    let r = b.relu("r", c1);
    let c2 = b.conv("c2", r, 8, 3, 1, 1);
    b.output(c2);
    let g = b.finish();
    let plan = ClusterPlan::gathered(
        2,
        SyncMode::Ring,
        vec![
            LayerScheme::Replicated,
            LayerScheme::InH,
            LayerScheme::InH,
            LayerScheme::InW,
        ],
    );
    let master = ParamStore::for_graph(&g);
    let inputs = synthetic_inputs(&g, 67);
    let want = Interpreter::new(&g).run(&inputs);
    let ga = Arc::new(g);
    let mesh = LocalTransport::mesh(2);
    let outs: Vec<Vec<Tensor>> = std::thread::scope(|scope| {
        let handles: Vec<_> = mesh
            .into_iter()
            .enumerate()
            .map(|(rank, t)| {
                let worker = ShardWorker::new(
                    ga.clone(),
                    plan.clone(),
                    ShardParams::extract(&ga, &plan, &master, rank),
                    Box::new(t),
                    1,
                );
                let inputs = inputs.clone();
                scope.spawn(move || worker.run(&inputs).expect("shard round"))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard thread")).collect()
    });
    for (rank, got) in outs.iter().enumerate() {
        assert_eq!(got[0].data, want[0].data, "rank {rank} diverged");
    }
}

/// Planned residency end to end: under the OutC scheme the small CNN's
/// `c1 → bn → relu → dw` chain keeps c1's activation shard-resident (the
/// planner skips its all-gather), the per-element chain carries the
/// slices, the depthwise conv consumes them aligned — and the output is
/// still bit-identical to the serial interpreter, with strictly fewer
/// sync bytes than the eager-gather baseline.
#[test]
fn resident_outc_chain_is_exact_and_saves_sync_bytes() {
    let g = small_cnn();
    let d = presets::tms320c6678();
    let inputs = synthetic_inputs(&g, 71);
    let want = Interpreter::new(&g).run(&inputs);
    let ga = Arc::new(g.clone());
    for p in [2usize, 4] {
        let driver =
            ClusterDriver::local(ga.clone(), &d, p, PartitionScheme::OutC, SyncMode::Ring, 1)
                .expect("cluster spins up");
        let acct = driver.plan().accounting(&g);
        assert!(acct.gathers_skipped >= 1, "p={p}: no gather skipped: {acct:?}");
        assert!(acct.sync_bytes < acct.gathered_bytes, "p={p}: {acct:?}");
        let got = driver.infer(&inputs).expect("cluster inference");
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.data, b.data, "p={p}: resident dataflow diverged from serial");
        }
        // The runtime counters agree with the plan: at least one gather
        // was skipped on rank 0 and no lazy re-gather paid it back.
        let stats = driver.sync_stats().expect("local cluster stats");
        assert!(stats.gathers_skipped >= 1, "p={p}: {stats:?}");
        // Residency must also beat the eager baseline in measured bytes.
        let base = ClusterDriver::local_opts(
            ga.clone(),
            &d,
            p,
            PartitionScheme::OutC,
            SyncMode::Ring,
            1,
            None,
            false,
        )
        .expect("baseline cluster spins up");
        let bgot = base.infer(&inputs).expect("baseline inference");
        for (a, b) in want.iter().zip(&bgot) {
            assert_eq!(a.data, b.data, "p={p}: baseline diverged from serial");
        }
        let bstats = base.sync_stats().expect("local cluster stats");
        assert_eq!(bstats.gathers_skipped, 0, "baseline must gather eagerly");
        assert!(
            stats.sync_bytes < bstats.sync_bytes,
            "p={p}: resident {} >= gathered {}",
            stats.sync_bytes,
            bstats.sync_bytes
        );
    }
}

/// A hand-built plan forces residency right before a spatially-sharded
/// consumer: the worker must lazily re-gather the channel-resident value
/// (the interrupted-chain path) and still match the serial interpreter
/// bit-for-bit on every rank.
#[test]
fn resident_chain_interrupted_by_spatial_op_regathers_exactly() {
    let mut b = GraphBuilder::new("cluster_resid_interrupt");
    let x = b.input("x", Shape::nchw(1, 4, 10, 10));
    let c1 = b.conv("c1", x, 8, 3, 1, 1);
    let r = b.relu("r", c1);
    let c2 = b.conv("c2", r, 8, 3, 1, 1);
    b.output(c2);
    let g = b.finish();
    let p = 2usize;
    // c1 OutC + resident, relu carries the slices, c2 is row-sharded —
    // a combination the cost model would never emit (it keeps the gather
    // eager); the executor must survive it anyway.
    let mut plan = ClusterPlan::gathered(
        p,
        SyncMode::Ring,
        vec![
            LayerScheme::Replicated,
            LayerScheme::OutC,
            LayerScheme::Replicated,
            LayerScheme::InH,
        ],
    );
    let slices = outc_slices(g.node(1), p).expect("conv slices");
    plan.residency[1] = Residency::ResidentOutC(slices.clone());
    plan.residency[2] = Residency::ResidentOutC(slices);
    let master = ParamStore::for_graph(&g);
    let inputs = synthetic_inputs(&g, 72);
    let want = Interpreter::new(&g).run(&inputs);
    let ga = Arc::new(g);
    let mesh = LocalTransport::mesh(p);
    let mut workers = Vec::new();
    let mut stats = Vec::new();
    for (rank, t) in mesh.into_iter().enumerate() {
        let worker = ShardWorker::new(
            ga.clone(),
            plan.clone(),
            ShardParams::extract(&ga, &plan, &master, rank),
            Box::new(t),
            1,
        );
        stats.push(worker.stats());
        workers.push(worker);
    }
    let outs: Vec<Vec<Tensor>> = std::thread::scope(|scope| {
        let handles: Vec<_> = workers
            .into_iter()
            .map(|w| {
                let inputs = inputs.clone();
                scope.spawn(move || w.run(&inputs).expect("shard round"))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard thread")).collect()
    });
    for (rank, got) in outs.iter().enumerate() {
        assert_eq!(got[0].data, want[0].data, "rank {rank} diverged");
    }
    for (rank, s) in stats.iter().enumerate() {
        let snap = s.snapshot();
        assert_eq!(snap.gathers_skipped, 1, "rank {rank}: c1 skipped its eager gather");
        assert!(
            snap.all_gathers >= 1,
            "rank {rank}: the spatial consumer must force the lazy re-gather"
        );
    }
}

#[test]
fn tcp_loopback_smoke_round_trips_a_model() {
    // Real TcpTransport workers on loopback: two dist-worker sessions,
    // full wire protocol (spec + shard weights + two inference rounds).
    let mut hosts = Vec::new();
    let mut servers = Vec::new();
    for _ in 0..2 {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        hosts.push(listener.local_addr().expect("local addr").to_string());
        servers.push(std::thread::spawn(move || serve_listener(&listener, Some(1))));
    }
    let driver = ClusterDriver::tcp(
        &hosts,
        "lstm",
        "tms320c6678",
        PartitionScheme::OutC,
        SyncMode::Ring,
        1,
    )
    .expect("tcp cluster connects");
    let g = models::lstm();
    let inputs = synthetic_inputs(&g, 68);
    let want = Interpreter::new(&g).run(&inputs);
    for round in 0..2 {
        let got = driver.infer(&inputs).expect("tcp inference");
        assert_eq!(got.len(), want.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.data, b.data, "round {round}: tcp cluster diverged");
        }
    }
    drop(driver); // sends shutdown; sessions end
    for s in servers {
        s.join().expect("worker thread").expect("worker session clean");
    }
}

#[test]
fn stale_peer_connection_does_not_kill_or_consume_a_worker_session() {
    // A stray connection speaking the peer-mesh protocol — e.g. a dial
    // left over from a torn-down session — must be dropped by
    // serve_listener without consuming the session budget or killing the
    // worker; a real driver session afterwards still completes.
    use std::io::Write;
    use std::net::TcpStream;

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let server = std::thread::spawn(move || serve_listener(&listener, Some(1)));

    let mut stale = TcpStream::connect(&addr).expect("stale connect");
    // A hand-rolled PEER_HELLO frame ([tag u64][len u32][rank u32], LE) —
    // the first thing a meshing peer, not a driver, would send.
    let mut frame = Vec::new();
    frame.extend_from_slice(&xenos::dist::exec::wire::PEER_HELLO.to_le_bytes());
    frame.extend_from_slice(&4u32.to_le_bytes());
    frame.extend_from_slice(&1u32.to_le_bytes());
    stale.write_all(&frame).expect("stale hello");
    drop(stale);
    std::thread::sleep(std::time::Duration::from_millis(100));

    let driver = ClusterDriver::tcp(
        &[addr],
        "lstm",
        "tms320c6678",
        PartitionScheme::OutC,
        SyncMode::Ring,
        1,
    )
    .expect("driver connects after the stale connection was dropped");
    let g = models::lstm();
    let inputs = synthetic_inputs(&g, 81);
    let want = Interpreter::new(&g).run(&inputs);
    let got = driver.infer(&inputs).expect("tcp inference");
    assert_eq!(got.len(), want.len());
    for (a, b) in want.iter().zip(&got) {
        assert_eq!(a.data, b.data, "single-worker tcp cluster diverged");
    }
    drop(driver); // sends shutdown; the one real session ends
    server.join().expect("worker thread").expect("worker served the real session");
}

#[test]
#[ignore = "slow in debug; run with --release -- --ignored"]
fn mobilenet_and_resnet_match_serial_across_schemes_and_sizes() {
    // The acceptance matrix: MobileNet + ResNet, outC/inH/mix, p ∈ {1,2,4}.
    for name in ["mobilenet", "resnet18"] {
        let g = models::by_name(name).unwrap_or_else(|| panic!("missing model {name}"));
        assert_cluster_matches_serial(
            &g,
            &[PartitionScheme::OutC, PartitionScheme::InH, PartitionScheme::Mix],
            &[1, 2, 4],
            SyncMode::Ring,
            1,
            69,
        );
    }
}

#[test]
#[ignore = "slow in debug; run with --release -- --ignored"]
fn mobilenet_ps_sync_matches_serial() {
    assert_cluster_matches_serial(
        &models::mobilenet(),
        &[PartitionScheme::Mix],
        &[4],
        SyncMode::Ps,
        1,
        70,
    );
}
