//! Whole-system integration: every experiment driver runs, every model
//! optimizes and simulates on every device, and the cross-cutting paper
//! claims hold simultaneously.

use xenos::graph::models;
use xenos::hw::presets;
use xenos::opt::OptLevel;
use xenos::sim::run_level;

#[test]
fn all_experiments_produce_tables() {
    for id in xenos::exp::ALL_EXPERIMENTS {
        let r = xenos::exp::run(id).unwrap_or_else(|| panic!("missing {id}"));
        assert_eq!(r.id, id);
        assert!(!r.tables.is_empty(), "{id} must render tables");
        for (caption, t) in &r.tables {
            assert!(!t.is_empty(), "{id}/{caption} is empty");
            assert!(t.render().contains('|'));
        }
    }
}

#[test]
fn every_model_runs_on_every_device_at_every_level() {
    for model in models::PAPER_BENCHMARKS {
        let g = models::by_name(model).unwrap();
        for device in [presets::tms320c6678(), presets::zcu102()] {
            let mut last = f64::INFINITY;
            for level in [OptLevel::Vanilla, OptLevel::HoOnly, OptLevel::Full] {
                let (o, r) = run_level(&g, &device, level);
                assert!(r.total_s > 0.0, "{model}/{}/{level:?}", device.name);
                assert!(r.total_s <= last * 1.001,
                    "{model}/{}: {level:?} slower than previous arm", device.name);
                assert_eq!(o.plan.nodes.len(), o.graph.len());
                o.graph.validate().unwrap();
                last = r.total_s;
            }
        }
    }
}

#[test]
fn optimizer_is_deterministic() {
    let d = presets::zcu102();
    let g = models::shufflenet();
    let a = xenos::opt::auto(&g, &d);
    let b = xenos::opt::auto(&g, &d);
    assert_eq!(a.fused, b.fused);
    assert_eq!(a.links.len(), b.links.len());
    assert_eq!(a.plan.peak_units(), b.plan.peak_units());
    for (x, y) in a.plan.nodes.iter().zip(&b.plan.nodes) {
        assert_eq!(x, y);
    }
}

#[test]
fn linked_graphs_report_table1_patterns() {
    // The paper's Table 1 pattern families all fire somewhere in the zoo.
    let mut seen = std::collections::HashSet::new();
    let d = presets::tms320c6678();
    for model in models::PAPER_BENCHMARKS {
        let g = models::by_name(model).unwrap();
        let o = xenos::opt::auto(&g, &d);
        for l in &o.links {
            seen.insert(l.pattern.clone());
        }
    }
    for expected in ["ConvX -> ConvY", "ConvX -> ConvY -> ZPooling", "MatmulX -> MatmulY"] {
        assert!(seen.contains(expected), "pattern {expected} never fired; saw {seen:?}");
    }
}

#[test]
fn headline_claims_hold_together() {
    // One test that asserts the paper's abstract, end to end:
    let tms = presets::tms320c6678();
    let zcu = presets::zcu102();
    let mut ho_cuts_tms = Vec::new();
    let mut vo_cuts_tms = Vec::new();
    let mut ho_cuts_zcu = Vec::new();
    let mut vo_cuts_zcu = Vec::new();
    for model in models::PAPER_BENCHMARKS {
        let g = models::by_name(model).unwrap();
        for (dev, hos, vos) in [
            (&tms, &mut ho_cuts_tms, &mut vo_cuts_tms),
            (&zcu, &mut ho_cuts_zcu, &mut vo_cuts_zcu),
        ] {
            let (_, v) = run_level(&g, dev, OptLevel::Vanilla);
            let (_, h) = run_level(&g, dev, OptLevel::HoOnly);
            let (_, f) = run_level(&g, dev, OptLevel::Full);
            hos.push(1.0 - h.total_s / v.total_s);
            vos.push(1.0 - f.total_s / h.total_s);
        }
    }
    let max = |v: &[f64]| v.iter().fold(0.0f64, |a, &b| a.max(b));
    // "reduce the inference time by 21.2%-84.9% and 17.9%-96.2%" — both
    // optimizations must produce substantial reductions somewhere.
    assert!(max(&ho_cuts_tms).max(max(&ho_cuts_zcu)) > 0.4, "HO must matter");
    assert!(max(&vo_cuts_tms).max(max(&vo_cuts_zcu)) > 0.4, "VO must matter");
    // The cross-device asymmetry (§7.2).
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(mean(&ho_cuts_zcu) > mean(&ho_cuts_tms), "HO stronger on the FPGA");
    assert!(mean(&vo_cuts_tms) > mean(&vo_cuts_zcu), "VO stronger on the DSP");
}

#[test]
fn simulated_times_are_edge_plausible() {
    // Sanity bound: single-digit-microsecond or multi-second inferences
    // would mean broken unit conversions somewhere.
    for model in models::PAPER_BENCHMARKS {
        let g = models::by_name(model).unwrap();
        let (_, r) = run_level(&g, &presets::tms320c6678(), OptLevel::Full);
        assert!(
            r.total_s > 1e-4 && r.total_s < 1.0,
            "{model}: {}s",
            r.total_s
        );
    }
}
