//! Differential suite for true batched execution: a batch of N samples
//! must produce outputs **element-wise identical** to N independent
//! single-sample runs, on every engine (serial interpreter, worker-pool
//! plan executor, INT8 engine, local d-Xenos cluster) at both
//! precisions — the batch dimension changes amortization, never
//! arithmetic. The sync-amortization test pins the headline property:
//! one cluster round (one set of collectives) per *batch*, not per
//! *sample*.

use std::sync::Arc;

use xenos::dist::exec::ClusterDriver;
use xenos::dist::{PartitionScheme, SyncMode};
use xenos::graph::{Graph, GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::ops::interp::synthetic_inputs;
use xenos::ops::params::ParamStore;
use xenos::ops::{Interpreter, ParInterpreter, Tensor};
use xenos::quant::{CalibTable, QuantEngine};

/// Small CNN covering dense/depthwise/pointwise convs, pooling, a
/// stride-2 downsample, FC and softmax — the shapes that exercise halo
/// exchange, OutC reassembly and partial-sum reduce-scatter.
fn cnn() -> Graph {
    let mut b = GraphBuilder::new("batched_cnn");
    let x = b.input("x", Shape::nchw(1, 4, 16, 16));
    let c1 = b.conv_bn_relu("c1", x, 16, 3, 1, 1);
    let dw = b.dw_bn_relu("dw", c1, 3, 1, 1);
    let pw = b.conv_bn_relu("pw", dw, 32, 1, 1, 0);
    let mp = b.maxpool("mp", pw, 2, 2);
    let c2 = b.conv("c2", mp, 16, 3, 2, 1);
    let gp = b.global_pool("gp", c2);
    let fc = b.fc("fc", gp, 10);
    let sm = b.softmax("sm", fc);
    b.output(sm);
    b.finish()
}

fn batch_for(g: &Graph, n: usize, seed0: u64) -> Vec<Vec<Tensor>> {
    (0..n).map(|s| synthetic_inputs(g, seed0 + s as u64)).collect()
}

fn assert_outputs_eq(want: &[Vec<Tensor>], got: &[Vec<Tensor>], label: &str) {
    assert_eq!(want.len(), got.len(), "{label}: batch arity");
    for (s, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.len(), g.len(), "{label}: sample {s} output arity");
        for (a, b) in w.iter().zip(g) {
            assert_eq!(a.shape(), b.shape(), "{label}: sample {s} shape");
            assert_eq!(a.data, b.data, "{label}: sample {s} diverged from solo run");
        }
    }
}

#[test]
fn interp_batch_matches_single_runs() {
    let g = cnn();
    let batch = batch_for(&g, 5, 100);
    let interp = Interpreter::new(&g);
    let want: Vec<Vec<Tensor>> = batch.iter().map(|b| interp.run(b)).collect();
    let got = interp.run_batch(&batch);
    assert_outputs_eq(&want, &got, "interp");
}

#[test]
fn par_interp_batch_matches_single_runs() {
    let g = Arc::new(cnn());
    let d = presets::tms320c6678();
    let batch = batch_for(&g, 5, 200);
    for workers in [1usize, 4] {
        let par = ParInterpreter::new(g.clone(), &d, workers);
        let want: Vec<Vec<Tensor>> = batch.iter().map(|b| par.run(b)).collect();
        let got = par.run_batch(&batch);
        assert_outputs_eq(&want, &got, &format!("par x{workers}"));
    }
}

#[test]
fn quant_batch_matches_single_runs() {
    let g = Arc::new(cnn());
    let params = ParamStore::for_graph(&g);
    let calib = CalibTable::synthetic(&g, &params, 3, 7);
    let batch = batch_for(&g, 5, 300);
    for threads in [1usize, 4] {
        let q = QuantEngine::new(g.clone(), &calib, threads).expect("quant engine");
        let want: Vec<Vec<Tensor>> = batch.iter().map(|b| q.run(b)).collect();
        let got = q.run_batch(&batch);
        assert_outputs_eq(&want, &got, &format!("quant x{threads}"));
    }
}

#[test]
fn cluster_batch_matches_single_runs_f32() {
    let g = Arc::new(cnn());
    let d = presets::tms320c6678();
    let batch = batch_for(&g, 3, 400);
    for scheme in [
        PartitionScheme::OutC,
        PartitionScheme::InH,
        PartitionScheme::InW,
        PartitionScheme::Mix,
    ] {
        for sync in [SyncMode::Ring, SyncMode::Ps] {
            let driver = ClusterDriver::local(g.clone(), &d, 2, scheme, sync, 1)
                .expect("cluster spins up");
            let want: Vec<Vec<Tensor>> =
                batch.iter().map(|b| driver.infer(b).expect("solo round")).collect();
            let got = driver.infer_batch(&batch).expect("batched round");
            assert_outputs_eq(&want, &got, &format!("cluster {scheme:?}/{sync:?}"));
        }
    }
}

#[test]
fn cluster_batch_matches_single_runs_int8() {
    let g = Arc::new(cnn());
    let d = presets::tms320c6678();
    let params = ParamStore::for_graph(&g);
    let calib = CalibTable::synthetic(&g, &params, 3, 7);
    let batch = batch_for(&g, 3, 500);
    for scheme in [PartitionScheme::OutC, PartitionScheme::InH, PartitionScheme::Mix] {
        for sync in [SyncMode::Ring, SyncMode::Ps] {
            let driver =
                ClusterDriver::local_q8(g.clone(), &d, 2, scheme, sync, 1, &calib)
                    .expect("int8 cluster spins up");
            let want: Vec<Vec<Tensor>> =
                batch.iter().map(|b| driver.infer(b).expect("solo round")).collect();
            let got = driver.infer_batch(&batch).expect("batched round");
            assert_outputs_eq(&want, &got, &format!("q8 cluster {scheme:?}/{sync:?}"));
        }
    }
}

/// The amortization headline: N samples in one batched round cost ONE
/// round of collectives, so rank 0's sync counters after `infer_batch`
/// of 8 are exactly 1/8 of eight sequential `infer` calls.
#[test]
fn batched_round_amortizes_sync_by_batch_size() {
    const N: usize = 8;
    let g = Arc::new(cnn());
    let d = presets::tms320c6678();
    let batch = batch_for(&g, N, 600);

    let solo = ClusterDriver::local(g.clone(), &d, 2, PartitionScheme::Mix, SyncMode::Ring, 1)
        .expect("cluster spins up");
    for sample in &batch {
        solo.infer(sample).expect("solo round");
    }
    let s = solo.sync_stats().expect("local stats");

    let batched =
        ClusterDriver::local(g.clone(), &d, 2, PartitionScheme::Mix, SyncMode::Ring, 1)
            .expect("cluster spins up");
    let out = batched.infer_batch(&batch).expect("batched round");
    assert_eq!(out.len(), N);
    let b = batched.sync_stats().expect("local stats");

    assert_eq!(s.rounds, N as u64, "sequential baseline runs one round per sample");
    assert_eq!(b.rounds, 1, "the whole batch is one round");
    assert_eq!(s.all_gathers, N as u64 * b.all_gathers, "all-gathers amortize by N");
    assert_eq!(
        s.halo_exchanges,
        N as u64 * b.halo_exchanges,
        "halo exchanges amortize by N"
    );
    assert_eq!(
        s.reduce_scatters,
        N as u64 * b.reduce_scatters,
        "reduce-scatters amortize by N"
    );
    // The batched round moves the same activations — just in N-sample
    // frames — so bytes are equal, not divided.
    assert_eq!(s.sync_bytes, b.sync_bytes, "payload bytes are batch-invariant");
}

/// Regression: consecutive batched calls reuse the (deepened) buffer
/// arena; reuse across the batch boundary must not corrupt outputs.
#[test]
fn arena_reuse_across_batched_calls_stays_bit_exact() {
    let g = Arc::new(cnn());
    let d = presets::tms320c6678();
    let par = ParInterpreter::new(g.clone(), &d, 4);
    let b1 = batch_for(&g, 4, 700);
    let b2 = batch_for(&g, 4, 800);
    // Solo references computed first so the arena state at the time of
    // the batched calls differs from a fresh engine — the reuse path.
    let want1: Vec<Vec<Tensor>> = b1.iter().map(|b| par.run(b)).collect();
    let want2: Vec<Vec<Tensor>> = b2.iter().map(|b| par.run(b)).collect();
    let got1 = par.run_batch(&b1);
    let got2 = par.run_batch(&b2);
    assert_outputs_eq(&want1, &got1, "arena reuse: first batch");
    assert_outputs_eq(&want2, &got2, "arena reuse: second batch");
    // And interleaved solo/batched calls on the same engine agree too.
    let solo_again = par.run(&b2[0]);
    assert_outputs_eq(
        &[want2[0].clone()],
        &[solo_again],
        "arena reuse: solo after batches",
    );
}
