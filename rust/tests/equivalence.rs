//! Equivalence suite: the optimizer must never change what a graph
//! computes. Every arm (Vanilla / HO / Full) of every test graph is
//! interpreted on the same random inputs and compared bit-for-bit against
//! the unoptimized graph.
//!
//! The second half is the **parallel-executor differential suite**: the
//! `ParInterpreter` (DOS split on a worker pool) must be element-wise
//! equal to the serial `Interpreter` across the model zoo and across
//! worker counts 1/2/4 — bit-for-bit for K-free splits, within float
//! tolerance for partial-sum (`SplitDim::C`) reductions.

use std::sync::Arc;

use xenos::graph::{models, Graph, GraphBuilder, PoolAttrs, Shape};
use xenos::hw::presets;
use xenos::ops::{Interpreter, ParInterpreter};
use xenos::opt::{optimize, OptLevel, OptimizeOptions};

fn assert_all_levels_equal(g: &Graph, seed: u64) {
    let d = presets::tms320c6678();
    let base = Interpreter::new(g).run_synthetic(seed);
    for level in [OptLevel::Vanilla, OptLevel::HoOnly, OptLevel::Full] {
        let o = optimize(g, &d, OptimizeOptions { level, search: false });
        o.graph.validate().expect("optimized graph valid");
        let out = Interpreter::new(&o.graph).run_synthetic(seed);
        assert_eq!(base.len(), out.len(), "{}: output arity {level:?}", g.name);
        for (a, b) in base.iter().zip(&out) {
            assert_eq!(a.data, b.data, "{}: {level:?} changed numerics", g.name);
        }
    }
}

#[test]
fn ds_block_with_pooling() {
    // The paper's Figure 5 structure: CBR -> CBR -> AvgPool chain.
    let mut b = GraphBuilder::new("fig5_block");
    let x = b.input("x", Shape::nchw(1, 8, 16, 16));
    let dw = b.dw_bn_relu("ds/dw", x, 3, 1, 1);
    let pw = b.conv_bn_relu("ds/pw", dw, 16, 1, 1, 0);
    let p = b.avgpool("pool", pw, 2, 2);
    let out = b.global_pool("gap", p);
    b.output(out);
    assert_all_levels_equal(&b.finish(), 10);
}

#[test]
fn maxpool_linking_cbrm() {
    let mut b = GraphBuilder::new("cbrm_block");
    let x = b.input("x", Shape::nchw(1, 4, 12, 12));
    let c = b.conv_bn_relu("c", x, 32, 3, 1, 1);
    let p = b.maxpool("mp", c, 2, 2);
    let f = b.fc("fc", p, 7);
    b.output(f);
    assert_all_levels_equal(&b.finish(), 11);
}

#[test]
fn residual_shortcut_pattern() {
    // Table 1's shortcut-connection pattern.
    let mut b = GraphBuilder::new("shortcut");
    let x = b.input("x", Shape::nchw(1, 8, 10, 10));
    let c1 = b.conv_bn_relu("c1", x, 8, 3, 1, 1);
    let c2 = b.conv("c2", c1, 8, 3, 1, 1);
    let add = b.add("add", c2, x);
    let r = b.relu("r", add);
    b.output(r);
    assert_all_levels_equal(&b.finish(), 12);
}

#[test]
fn concat_branches_fire_module() {
    let mut b = GraphBuilder::new("fire");
    let x = b.input("x", Shape::nchw(1, 16, 8, 8));
    let sq = b.conv_bn_relu("squeeze", x, 4, 1, 1, 0);
    let e1 = b.conv_bn_relu("e1", sq, 8, 1, 1, 0);
    let e3 = b.conv_bn_relu("e3", sq, 8, 3, 1, 1);
    let cat = b.concat("cat", &[e1, e3]);
    b.output(cat);
    assert_all_levels_equal(&b.finish(), 13);
}

#[test]
fn matmul_transpose_chain() {
    // The MatmulX -> MatmulY linking pattern (attention shape).
    let mut b = GraphBuilder::new("attn");
    let q = b.input("q", Shape::mat(16, 8));
    let k = b.input("k", Shape::mat(16, 8));
    let v = b.input("v", Shape::mat(16, 8));
    let kt = b.transpose("kt", k);
    let s = b.matmul("scores", q, kt);
    let sm = b.softmax("sm", s);
    let ctx = b.matmul("ctx", sm, v);
    let ln = b.layernorm("ln", ctx);
    b.output(ln);
    assert_all_levels_equal(&b.finish(), 14);
}

#[test]
fn channel_shuffle_unit() {
    let mut b = GraphBuilder::new("shuffle_unit");
    let x = b.input("x", Shape::nchw(1, 16, 8, 8));
    let g1 = b.gconv("g1", x, 16, 1, 1, 0, 4);
    let sh = b.channel_shuffle("sh", g1, 4);
    let dw = b.dwconv("dw", sh, 3, 1, 1);
    let g2 = b.gconv("g2", dw, 16, 1, 1, 0, 4);
    let add = b.add("add", g2, x);
    b.output(add);
    assert_all_levels_equal(&b.finish(), 15);
}

#[test]
fn upsample_decoder() {
    let mut b = GraphBuilder::new("decoder");
    let x = b.input("x", Shape::nchw(1, 8, 4, 4));
    let u = b.upsample("up", x, 2);
    let c = b.conv_bn_relu("c", u, 4, 3, 1, 1);
    let s = b.sigmoid("sig", c);
    b.output(s);
    assert_all_levels_equal(&b.finish(), 16);
}

#[test]
fn lstm_cell_step() {
    // Mac + sigmoid/tanh + slice/transpose (LSTM structure, one step).
    let mut b = GraphBuilder::new("lstm_step");
    let x = b.input("x", Shape::mat(8, 4));
    let h = b.input("h", Shape::mat(1, 16));
    let c = b.input("c", Shape::mat(1, 16));
    let xt_col = b.slice_c("xcol", x, 0, 1);
    let xt = b.transpose("xt", xt_col);
    let wx = b.fc("wx", xt, 16);
    let wh = b.fc("wh", h, 16);
    let pre = b.add("pre", wx, wh);
    let i = b.sigmoid("i", pre);
    let g = b.tanh("g", pre);
    let ig = b.mul("ig", i, g);
    let c2 = b.mac("c2", i, c, ig);
    let hout = b.mul("h2", i, c2);
    b.output(hout);
    assert_all_levels_equal(&b.finish(), 17);
}

#[test]
fn full_lstm_model_equivalence() {
    // The full unrolled LSTM zoo model is small enough to interpret.
    assert_all_levels_equal(&models::lstm(), 18);
}

#[test]
fn overlapping_pool_not_linked_but_equal() {
    let mut b = GraphBuilder::new("overlap");
    let x = b.input("x", Shape::nchw(1, 4, 9, 9));
    let c = b.conv_bn_relu("c", x, 8, 1, 1, 0);
    let p = b.pool("p", c, PoolAttrs::max(3, 1));
    b.output(p);
    assert_all_levels_equal(&b.finish(), 19);
}

/// Parallel executor vs serial interpreter, bit-for-bit, across worker
/// counts. Worker count 1 doubles as the regression guard that a 1-worker
/// pool degenerates to the serial path exactly.
fn assert_par_matches_serial(g: &Graph, seed: u64) {
    let d = presets::tms320c6678();
    let base = Interpreter::new(g).run_synthetic(seed);
    let ga = Arc::new(g.clone());
    for workers in [1usize, 2, 4] {
        let par = ParInterpreter::new(ga.clone(), &d, workers);
        let out = par.run_synthetic(seed);
        assert_eq!(base.len(), out.len(), "{}: arity (workers={workers})", g.name);
        for (a, b) in base.iter().zip(&out) {
            assert_eq!(
                a.data, b.data,
                "{}: parallel executor with {workers} workers changed numerics",
                g.name
            );
        }
    }
}

#[test]
fn par_exec_matches_serial_conv_blocks() {
    // Depthwise-separable block with pooling (the Figure 5 structure).
    let mut b = GraphBuilder::new("par_ds_block");
    let x = b.input("x", Shape::nchw(1, 8, 16, 16));
    let dw = b.dw_bn_relu("ds/dw", x, 3, 1, 1);
    let pw = b.conv_bn_relu("ds/pw", dw, 16, 1, 1, 0);
    let p = b.avgpool("pool", pw, 2, 2);
    let c = b.conv("head", p, 8, 3, 2, 1);
    let gp = b.global_pool("gap", c);
    let fc = b.fc("fc", gp, 10);
    let sm = b.softmax("sm", fc);
    b.output(sm);
    assert_par_matches_serial(&b.finish(), 40);
}

#[test]
fn par_exec_matches_serial_branchy_blocks() {
    // Fire module (concat) + shuffle unit (grouped pointwise + shortcut).
    let mut b = GraphBuilder::new("par_branchy");
    let x = b.input("x", Shape::nchw(1, 16, 8, 8));
    let sq = b.conv_bn_relu("squeeze", x, 4, 1, 1, 0);
    let e1 = b.conv_bn_relu("e1", sq, 8, 1, 1, 0);
    let e3 = b.conv_bn_relu("e3", sq, 8, 3, 1, 1);
    let cat = b.concat("cat", &[e1, e3]);
    let g1 = b.gconv("g1", cat, 16, 1, 1, 0, 4);
    let sh = b.channel_shuffle("sh", g1, 4);
    let dw = b.dwconv("dw", sh, 3, 1, 1);
    let add = b.add("add", dw, cat);
    b.output(add);
    assert_par_matches_serial(&b.finish(), 41);
}

#[test]
fn par_exec_matches_serial_attention_chain() {
    // Two-operand matmul + softmax/layernorm/gelu row ops at a size that
    // crosses the parallelization threshold.
    let mut b = GraphBuilder::new("par_attn");
    let q = b.input("q", Shape::mat(64, 64));
    let k = b.input("k", Shape::mat(64, 64));
    let s = b.matmul("scores", q, k);
    let sm = b.softmax("sm", s);
    let ln = b.layernorm("ln", sm);
    let gl = b.gelu("gelu", ln);
    let ad = b.add("add", gl, sm);
    let fc = b.fc("fc", ad, 32);
    b.output(fc);
    assert_par_matches_serial(&b.finish(), 42);
}

#[test]
fn par_exec_matches_serial_lstm_zoo_model() {
    assert_par_matches_serial(&models::lstm(), 43);
}

#[test]
fn par_exec_matches_serial_on_fully_optimized_graph() {
    // Run the optimizer at Full level (CBR fusion + linking: the graph now
    // contains Cbr/Cbra fused nodes) and check the parallel executor on
    // the rewritten graph too.
    let mut b = GraphBuilder::new("par_opt");
    let x = b.input("x", Shape::nchw(1, 8, 16, 16));
    let c1 = b.conv_bn_relu("c1", x, 16, 3, 1, 1);
    let p = b.avgpool("p", c1, 2, 2);
    let c2 = b.conv_bn_relu("c2", p, 32, 1, 1, 0);
    let mp = b.maxpool("mp", c2, 2, 2);
    let fc = b.fc("fc", mp, 10);
    b.output(fc);
    let g = b.finish();
    let d = presets::tms320c6678();
    let o = optimize(&g, &d, OptimizeOptions { level: OptLevel::Full, search: false });
    assert_par_matches_serial(&o.graph, 44);
}

#[test]
fn one_worker_pool_is_reported_and_huge_requests_clamp() {
    let g = Arc::new(models::lstm());
    let d = presets::tms320c6678();
    let one = ParInterpreter::new(g.clone(), &d, 1);
    assert_eq!(one.workers(), 1, "explicit 1-worker pool must stay serial");
    let huge = ParInterpreter::new(g, &d, 1 << 20);
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    assert!(
        huge.workers() >= 1 && huge.workers() <= host,
        "worker pool must clamp to available_parallelism ({host}), got {}",
        huge.workers()
    );
}

#[test]
fn par_exec_c_split_reduction_is_tolerance_equal() {
    // One kernel slice (in_c*kh*kw*4 bytes) exceeds half the private L2 of
    // the TMS preset, forcing a SplitDim::C parameter split with a
    // partial-sum reduction — the one path where the parallel executor is
    // tolerance-equal instead of bit-equal.
    let mut b = GraphBuilder::new("par_csplit");
    let x = b.input("x", Shape::nchw(1, 8192, 6, 6));
    let c = b.conv("c", x, 4, 3, 1, 1);
    b.output(c);
    let g = b.finish();
    let d = presets::tms320c6678();
    let ga = Arc::new(g.clone());
    let par = ParInterpreter::new(ga, &d, 4);
    let split = par.plan().node(1).param_split.expect("plan must split params");
    assert!(split.needs_reduction, "C-split must be a reduction split");
    let base = Interpreter::new(&g).run_synthetic(45);
    let out = par.run_synthetic(45);
    // 73k-term dot products summed in two different orders: allow the
    // reduction a few ulp-random-walks of slack.
    base[0].assert_close(&out[0], 1e-3);
}

#[test]
#[ignore = "slow in debug; run with --release -- --ignored"]
fn par_exec_full_zoo_differential() {
    // The full differential matrix: every zoo model, serial vs parallel,
    // worker counts 1/2/4.
    for name in [
        "mobilenet",
        "squeezenet",
        "shufflenet",
        "resnet18",
        "resnet101",
        "centrenet",
        "lstm",
        "bert_s",
        "bert_l",
    ] {
        let g = models::by_name(name).unwrap_or_else(|| panic!("missing model {name}"));
        assert_par_matches_serial(&g, 46);
    }
}

#[test]
#[ignore = "slow in debug; run with --release -- --ignored"]
fn full_mobilenet_equivalence() {
    assert_all_levels_equal(&models::mobilenet(), 20);
}

#[test]
#[ignore = "slow in debug; run with --release -- --ignored"]
fn full_squeezenet_equivalence() {
    assert_all_levels_equal(&models::squeezenet(), 21);
}

#[test]
#[ignore = "slow in debug; run with --release -- --ignored"]
fn full_bert_s_equivalence() {
    assert_all_levels_equal(&models::bert_s(), 22);
}
