//! Equivalence suite: the optimizer must never change what a graph
//! computes. Every arm (Vanilla / HO / Full) of every test graph is
//! interpreted on the same random inputs and compared bit-for-bit against
//! the unoptimized graph.

use xenos::graph::{models, Graph, GraphBuilder, PoolAttrs, Shape};
use xenos::hw::presets;
use xenos::ops::Interpreter;
use xenos::opt::{optimize, OptLevel, OptimizeOptions};

fn assert_all_levels_equal(g: &Graph, seed: u64) {
    let d = presets::tms320c6678();
    let base = Interpreter::new(g).run_synthetic(seed);
    for level in [OptLevel::Vanilla, OptLevel::HoOnly, OptLevel::Full] {
        let o = optimize(g, &d, OptimizeOptions { level, search: false });
        o.graph.validate().expect("optimized graph valid");
        let out = Interpreter::new(&o.graph).run_synthetic(seed);
        assert_eq!(base.len(), out.len(), "{}: output arity {level:?}", g.name);
        for (a, b) in base.iter().zip(&out) {
            assert_eq!(a.data, b.data, "{}: {level:?} changed numerics", g.name);
        }
    }
}

#[test]
fn ds_block_with_pooling() {
    // The paper's Figure 5 structure: CBR -> CBR -> AvgPool chain.
    let mut b = GraphBuilder::new("fig5_block");
    let x = b.input("x", Shape::nchw(1, 8, 16, 16));
    let dw = b.dw_bn_relu("ds/dw", x, 3, 1, 1);
    let pw = b.conv_bn_relu("ds/pw", dw, 16, 1, 1, 0);
    let p = b.avgpool("pool", pw, 2, 2);
    let out = b.global_pool("gap", p);
    b.output(out);
    assert_all_levels_equal(&b.finish(), 10);
}

#[test]
fn maxpool_linking_cbrm() {
    let mut b = GraphBuilder::new("cbrm_block");
    let x = b.input("x", Shape::nchw(1, 4, 12, 12));
    let c = b.conv_bn_relu("c", x, 32, 3, 1, 1);
    let p = b.maxpool("mp", c, 2, 2);
    let f = b.fc("fc", p, 7);
    b.output(f);
    assert_all_levels_equal(&b.finish(), 11);
}

#[test]
fn residual_shortcut_pattern() {
    // Table 1's shortcut-connection pattern.
    let mut b = GraphBuilder::new("shortcut");
    let x = b.input("x", Shape::nchw(1, 8, 10, 10));
    let c1 = b.conv_bn_relu("c1", x, 8, 3, 1, 1);
    let c2 = b.conv("c2", c1, 8, 3, 1, 1);
    let add = b.add("add", c2, x);
    let r = b.relu("r", add);
    b.output(r);
    assert_all_levels_equal(&b.finish(), 12);
}

#[test]
fn concat_branches_fire_module() {
    let mut b = GraphBuilder::new("fire");
    let x = b.input("x", Shape::nchw(1, 16, 8, 8));
    let sq = b.conv_bn_relu("squeeze", x, 4, 1, 1, 0);
    let e1 = b.conv_bn_relu("e1", sq, 8, 1, 1, 0);
    let e3 = b.conv_bn_relu("e3", sq, 8, 3, 1, 1);
    let cat = b.concat("cat", &[e1, e3]);
    b.output(cat);
    assert_all_levels_equal(&b.finish(), 13);
}

#[test]
fn matmul_transpose_chain() {
    // The MatmulX -> MatmulY linking pattern (attention shape).
    let mut b = GraphBuilder::new("attn");
    let q = b.input("q", Shape::mat(16, 8));
    let k = b.input("k", Shape::mat(16, 8));
    let v = b.input("v", Shape::mat(16, 8));
    let kt = b.transpose("kt", k);
    let s = b.matmul("scores", q, kt);
    let sm = b.softmax("sm", s);
    let ctx = b.matmul("ctx", sm, v);
    let ln = b.layernorm("ln", ctx);
    b.output(ln);
    assert_all_levels_equal(&b.finish(), 14);
}

#[test]
fn channel_shuffle_unit() {
    let mut b = GraphBuilder::new("shuffle_unit");
    let x = b.input("x", Shape::nchw(1, 16, 8, 8));
    let g1 = b.gconv("g1", x, 16, 1, 1, 0, 4);
    let sh = b.channel_shuffle("sh", g1, 4);
    let dw = b.dwconv("dw", sh, 3, 1, 1);
    let g2 = b.gconv("g2", dw, 16, 1, 1, 0, 4);
    let add = b.add("add", g2, x);
    b.output(add);
    assert_all_levels_equal(&b.finish(), 15);
}

#[test]
fn upsample_decoder() {
    let mut b = GraphBuilder::new("decoder");
    let x = b.input("x", Shape::nchw(1, 8, 4, 4));
    let u = b.upsample("up", x, 2);
    let c = b.conv_bn_relu("c", u, 4, 3, 1, 1);
    let s = b.sigmoid("sig", c);
    b.output(s);
    assert_all_levels_equal(&b.finish(), 16);
}

#[test]
fn lstm_cell_step() {
    // Mac + sigmoid/tanh + slice/transpose (LSTM structure, one step).
    let mut b = GraphBuilder::new("lstm_step");
    let x = b.input("x", Shape::mat(8, 4));
    let h = b.input("h", Shape::mat(1, 16));
    let c = b.input("c", Shape::mat(1, 16));
    let xt_col = b.slice_c("xcol", x, 0, 1);
    let xt = b.transpose("xt", xt_col);
    let wx = b.fc("wx", xt, 16);
    let wh = b.fc("wh", h, 16);
    let pre = b.add("pre", wx, wh);
    let i = b.sigmoid("i", pre);
    let g = b.tanh("g", pre);
    let ig = b.mul("ig", i, g);
    let c2 = b.mac("c2", i, c, ig);
    let hout = b.mul("h2", i, c2);
    b.output(hout);
    assert_all_levels_equal(&b.finish(), 17);
}

#[test]
fn full_lstm_model_equivalence() {
    // The full unrolled LSTM zoo model is small enough to interpret.
    assert_all_levels_equal(&models::lstm(), 18);
}

#[test]
fn overlapping_pool_not_linked_but_equal() {
    let mut b = GraphBuilder::new("overlap");
    let x = b.input("x", Shape::nchw(1, 4, 9, 9));
    let c = b.conv_bn_relu("c", x, 8, 1, 1, 0);
    let p = b.pool("p", c, PoolAttrs::max(3, 1));
    b.output(p);
    assert_all_levels_equal(&b.finish(), 19);
}

#[test]
#[ignore = "slow in debug; run with --release -- --ignored"]
fn full_mobilenet_equivalence() {
    assert_all_levels_equal(&models::mobilenet(), 20);
}

#[test]
#[ignore = "slow in debug; run with --release -- --ignored"]
fn full_squeezenet_equivalence() {
    assert_all_levels_equal(&models::squeezenet(), 21);
}

#[test]
#[ignore = "slow in debug; run with --release -- --ignored"]
fn full_bert_s_equivalence() {
    assert_all_levels_equal(&models::bert_s(), 22);
}
