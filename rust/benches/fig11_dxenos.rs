//! Bench target for paper Figure 11: d-Xenos distributed inference — the
//! analytic scheme×sync table, the cost of Algorithm 1's profiling
//! enumeration, the real ring all-reduce collective, and (new with the
//! `dist::exec` runtime) measured end-to-end distributed inference over
//! `LocalTransport` shard workers at p ∈ {1, 2, 4}, printed next to the
//! simulator's predictions for EXPERIMENTS.md.

use std::sync::Arc;

use xenos::dist::exec::ClusterDriver;
use xenos::dist::{enumerate_schemes, ring, simulate_dxenos, PartitionScheme, SyncMode};
use xenos::graph::models;
use xenos::hw::presets;
use xenos::ops::interp::synthetic_inputs;
use xenos::util::bench::bench;
use xenos::util::rng::Rng;

fn main() {
    xenos::exp::run("fig11").expect("registered").print();

    let d = presets::tms320c6678();
    let g = models::resnet101();
    bench("algorithm-1 scheme enumeration (resnet101, p=4)", 1, 10, || {
        enumerate_schemes(&g, &d, 4, SyncMode::Ring).0
    });

    let mut rng = Rng::new(1);
    let inputs: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_uniform(1 << 18)).collect();
    bench("ring all-reduce 4x1M floats (real exchange)", 1, 10, || {
        ring::ring_allreduce_exec(inputs.clone()).len()
    });

    // Real distributed execution vs the analytic prediction, MobileNet on
    // in-process shard workers. Absolute times are host times (threads on
    // one machine, not an SRIO cluster); the per-p scaling shape is the
    // comparable quantity.
    let mobilenet = Arc::new(models::mobilenet());
    let feed = synthetic_inputs(&mobilenet, 7);
    for p in [1usize, 2, 4] {
        let sim = simulate_dxenos(&mobilenet, &d, p, PartitionScheme::Mix, SyncMode::Ring);
        println!(
            "analytic mobilenet ring-Mix p={p}: {:.2}x predicted speedup",
            sim.speedup()
        );
        let driver = ClusterDriver::local(
            mobilenet.clone(),
            &d,
            p,
            PartitionScheme::Mix,
            SyncMode::Ring,
            1,
        )
        .expect("cluster spins up");
        bench(&format!("dist-exec mobilenet ring-Mix p={p} (real)"), 1, 5, || {
            driver.infer(&feed).expect("cluster inference").len()
        });
    }
}
