//! Bench target for paper Figure 11: d-Xenos distributed inference — the
//! scheme×sync table plus the cost of Algorithm 1's profiling enumeration
//! and of the real ring all-reduce collective.

use xenos::dist::{enumerate_schemes, ring, SyncMode};
use xenos::graph::models;
use xenos::hw::presets;
use xenos::util::bench::bench;
use xenos::util::rng::Rng;

fn main() {
    xenos::exp::run("fig11").expect("registered").print();

    let d = presets::tms320c6678();
    let g = models::resnet101();
    bench("algorithm-1 scheme enumeration (resnet101, p=4)", 1, 10, || {
        enumerate_schemes(&g, &d, 4, SyncMode::Ring).0
    });

    let mut rng = Rng::new(1);
    let inputs: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_uniform(1 << 18)).collect();
    bench("ring all-reduce 4x1M floats (real exchange)", 1, 10, || {
        ring::ring_allreduce_exec(inputs.clone()).len()
    });
}
