//! Bench target for paper Tables 4/5: operator micro-benchmarks (linking
//! via the trace-driven cache simulator, split via the cost model), plus
//! the cache simulator's own throughput.

use xenos::graph::DataLayout;
use xenos::sim::cache::{pool_consumer_trace, CacheSim};
use xenos::util::bench::bench;

fn main() {
    xenos::exp::run("table45").expect("registered").print();

    let trace = pool_consumer_trace(DataLayout::Chw, 64, 112, 112, 2);
    println!("cache-sim trace: {} accesses", trace.len());
    bench("cache-sim replay 800K accesses", 1, 10, || {
        let mut c = CacheSim::new(32 * 1024, 64, 4);
        c.run(trace.iter().copied());
        c.misses
    });
}
