//! Bench target for paper Figure 9: MobileNet memory-resource traces on
//! the TMS320C6678 (Vanilla vs Xenos) and the trace-generation cost.

use xenos::graph::models;
use xenos::hw::presets;
use xenos::opt::OptLevel;
use xenos::sim::{run_level, trace};
use xenos::util::bench::bench;

fn main() {
    xenos::exp::run("fig9").expect("registered").print();

    let g = models::mobilenet();
    let d = presets::tms320c6678();
    let (_, report) = run_level(&g, &d, OptLevel::Vanilla);
    bench("resample 16-bin trace", 5, 100, || trace::resample(&report.trace, 16).len());
}
