//! Bench target for paper Figure 7 (a) and (b): regenerates both inference-
//! time ablation tables and times the simulation path itself.
//!
//! ```bash
//! cargo bench --offline --bench fig7_inference_time
//! ```

use xenos::graph::models;
use xenos::hw::presets;
use xenos::opt::OptLevel;
use xenos::sim::run_level;
use xenos::util::bench::bench;

fn main() {
    xenos::exp::run("fig7a").expect("registered").print();
    xenos::exp::run("fig7b").expect("registered").print();

    // Perf tracking: full optimize+simulate loop per device.
    let g = models::mobilenet();
    for d in [presets::tms320c6678(), presets::zcu102()] {
        bench(
            &format!("optimize+simulate mobilenet on {}", d.name),
            2,
            20,
            || run_level(&g, &d, OptLevel::Full).1.total_s,
        );
    }
}
