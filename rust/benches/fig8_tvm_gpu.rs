//! Bench target for paper Figure 8: Xenos vs TVM vs PyTorch-GPU, plus the
//! wall-clock cost of the TVM-like enumeration itself.

use xenos::baselines::tvm_like;
use xenos::graph::models;
use xenos::hw::presets;
use xenos::util::bench::bench;

fn main() {
    xenos::exp::run("fig8").expect("registered").print();

    let d = presets::zcu102();
    let g = models::resnet18();
    bench("tvm-like enumeration+autotune resnet18", 1, 10, || {
        tvm_like(&g, &d).candidates_evaluated
    });
    bench("xenos auto-optimize resnet18", 1, 10, || {
        xenos::opt::auto(&g, &d).fused
    });
}
