//! Serving load benchmark — the coordinator under a sustained synthetic
//! request stream, reported per stage: queue wait, batch assembly, engine
//! execution, and end-to-end latency, for the serial and the parallel
//! zoo-model engines.
//!
//! Pass `--out BENCH_serve.json` (after `cargo bench -- `) or set
//! `BENCH_OUT` to also write the machine-readable suite document
//! (schema `xenos-bench-v1`) that pins the serving-perf trajectory per PR.

use std::sync::Arc;

use xenos::graph::{GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::runtime::Engine;
use xenos::serve::{coordinator::synthetic_requests, BatcherConfig, Coordinator, ServeConfig};
use xenos::util::bench::BenchSet;
use xenos::util::human_time;

/// `--out PATH` (after `cargo bench -- `) or the `BENCH_OUT` env var.
fn out_path() -> Option<String> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--out" {
            return args.next();
        }
    }
    std::env::var("BENCH_OUT").ok()
}

/// The small CNN block every serving worker executes.
fn serve_block() -> xenos::Graph {
    let mut b = GraphBuilder::new("serve_block");
    let x = b.input("x", Shape::nchw(1, 16, 16, 16));
    let c1 = b.conv_bn_relu("c1", x, 32, 3, 1, 1);
    let p = b.avgpool("p", c1, 2, 2);
    let f = b.fc("fc", p, 10);
    let s = b.softmax("sm", f);
    b.output(s);
    b.finish()
}

fn main() {
    let mut set = BenchSet::new("serve");
    let g = Arc::new(serve_block());
    let shapes: Vec<Shape> =
        g.input_ids().iter().map(|&i| g.node(i).out.shape.clone()).collect();

    for (label, threads) in [("interp", 1usize), ("par x2", 2)] {
        let cfg = ServeConfig {
            workers: 2,
            engine_threads: threads,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(1),
            },
            ..Default::default()
        };
        let gg = g.clone();
        let report = Coordinator::new(cfg)
            .run(
                move |_w| {
                    Ok(if threads > 1 {
                        let d = presets::tms320c6678();
                        Engine::par_interp(gg.clone(), &d, threads)
                    } else {
                        Engine::interp(gg.clone())
                    })
                },
                synthetic_requests(shapes.clone(), 256, 0.0, 9),
            )
            .expect("serve run");
        println!(
            "serve[{label}]: {} requests at {:.1} req/s — latency p50 {}, exec p50 {}, \
             queue p50 {}, assembly p50 {}",
            report.served,
            report.throughput,
            human_time(report.latency.p50),
            human_time(report.exec.p50),
            human_time(report.queue.p50),
            human_time(report.assembly.p50),
        );
        set.push(&format!("serve[{label}]: latency"), report.latency);
        set.push(&format!("serve[{label}]: exec"), report.exec);
        set.push(&format!("serve[{label}]: queue"), report.queue);
        set.push(&format!("serve[{label}]: assembly"), report.assembly);
    }

    if let Some(path) = out_path() {
        set.write(&path).expect("writing bench document");
    }
}
