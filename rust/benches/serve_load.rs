//! Serving load benchmark — the coordinator under a sustained synthetic
//! request stream, reported per stage: queue wait, batch assembly, engine
//! execution, and end-to-end latency, for the serial and the parallel
//! zoo-model engines; plus the TCP ingest front door priced over
//! loopback, including the load-shedding path under deliberate overload.
//!
//! Pass `--out BENCH_serve.json` (after `cargo bench -- `) or set
//! `BENCH_OUT` to also write the machine-readable suite document
//! (schema `xenos-bench-v1`) that pins the serving-perf trajectory per PR.

use std::sync::Arc;

use xenos::graph::{GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::runtime::Engine;
use xenos::serve::{
    client::drive_load, coordinator::synthetic_requests, BatcherConfig, Coordinator, IngestConfig,
    IngestServer, ModelRegistry, ServeConfig, ServeReport,
};
use xenos::util::bench::BenchSet;
use xenos::util::human_time;
use xenos::util::stats::Summary;

/// `--out PATH` (after `cargo bench -- `) or the `BENCH_OUT` env var.
fn out_path() -> Option<String> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--out" {
            return args.next();
        }
    }
    std::env::var("BENCH_OUT").ok()
}

/// The small CNN block every serving worker executes.
fn serve_block() -> xenos::Graph {
    let mut b = GraphBuilder::new("serve_block");
    let x = b.input("x", Shape::nchw(1, 16, 16, 16));
    let c1 = b.conv_bn_relu("c1", x, 32, 3, 1, 1);
    let p = b.avgpool("p", c1, 2, 2);
    let f = b.fc("fc", p, 10);
    let s = b.softmax("sm", f);
    b.output(s);
    b.finish()
}

/// Per-sample amortized engine time: each response's `exec_s` covers the
/// whole batch it was served in, so divide by its batch size. This keeps
/// the `exec` entries comparable with pre-batching baselines, where one
/// response was one engine call.
fn per_sample_exec(report: &ServeReport) -> Summary {
    let xs: Vec<f64> = report
        .responses
        .iter()
        .map(|r| r.exec_s / (r.batch_size.max(1) as f64))
        .collect();
    Summary::of(&xs).expect("at least one response")
}

fn main() {
    let mut set = BenchSet::new("serve");
    let g = Arc::new(serve_block());
    let shapes: Vec<Shape> =
        g.input_ids().iter().map(|&i| g.node(i).out.shape.clone()).collect();

    for (label, threads) in [("interp", 1usize), ("par x2", 2)] {
        let cfg = ServeConfig {
            workers: 2,
            engine_threads: threads,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(1),
            },
            ..Default::default()
        };
        let gg = g.clone();
        let report = Coordinator::new(cfg)
            .run(
                move |_w| {
                    Ok(if threads > 1 {
                        let d = presets::tms320c6678();
                        Engine::par_interp(gg.clone(), &d, threads)
                    } else {
                        Engine::interp(gg.clone())
                    })
                },
                synthetic_requests(shapes.clone(), 256, 0.0, 9),
            )
            .expect("serve run");
        let exec = per_sample_exec(&report);
        println!(
            "serve[{label}]: {} requests at {:.1} req/s — latency p50 {}, exec p50 {}, \
             queue p50 {}, assembly p50 {}",
            report.served,
            report.throughput,
            human_time(report.latency.p50),
            human_time(exec.p50),
            human_time(report.queue.p50),
            human_time(report.assembly.p50),
        );
        set.push(&format!("serve[{label}]: latency"), report.latency);
        set.push(&format!("serve[{label}]: exec"), exec);
        set.push(&format!("serve[{label}]: queue"), report.queue);
        set.push(&format!("serve[{label}]: assembly"), report.assembly);
    }

    // Batch-size sweep: the same engine and request stream served at
    // max_batch 1/4/8 — the amortization curve of true batched
    // execution. `sample time` is the inverse throughput (wall seconds
    // per served request, lower = faster), so the gate reads a
    // throughput loss as a regression like any other timing entry.
    for batch in [1usize, 4, 8] {
        let cfg = ServeConfig {
            workers: 2,
            engine_threads: 1,
            batcher: BatcherConfig {
                max_batch: batch,
                max_wait: std::time::Duration::from_millis(1),
            },
            ..Default::default()
        };
        let gg = g.clone();
        let report = Coordinator::new(cfg)
            .run(
                move |_w| Ok(Engine::interp(gg.clone())),
                synthetic_requests(shapes.clone(), 256, 0.0, 9),
            )
            .expect("serve run");
        let exec = per_sample_exec(&report);
        let sample_time =
            Summary::of(&[report.wall_s / report.served.max(1) as f64]).expect("one value");
        println!(
            "serve[batch {batch}]: {} requests at {:.1} req/s (fill {:.2}) — \
             per-sample latency p50 {}, per-sample exec p50 {}",
            report.served,
            report.throughput,
            report.batch_fill,
            human_time(report.latency.p50),
            human_time(exec.p50),
        );
        set.push(&format!("serve[batch {batch}]: per-sample latency"), report.latency);
        set.push(&format!("serve[batch {batch}]: per-sample exec"), exec);
        set.push(&format!("serve[batch {batch}]: sample time"), sample_time);
    }

    // The same block behind the TCP front door: a full loopback
    // round-trip (encode → admission → batch → engine → decode) priced
    // at batch 1 and batch 8. Closed-loop lanes stay under the default
    // admission bound, so nothing sheds here.
    for (label, max_batch, lanes) in [("batch 1", 1usize, 2usize), ("batch 8", 8, 16)] {
        let mut registry = ModelRegistry::new();
        let gg = g.clone();
        registry.register(
            "bench",
            shapes.clone(),
            2,
            BatcherConfig { max_batch, max_wait: std::time::Duration::from_millis(1) },
            move |_w| Ok(Engine::interp(gg.clone())),
        );
        let mut server = IngestServer::start("127.0.0.1:0", registry, IngestConfig::default())
            .expect("ingest server");
        let report = drive_load(
            &server.local_addr().to_string(),
            "bench",
            &shapes,
            256,
            lanes,
            0,
            std::time::Duration::from_secs(30),
            9,
        )
        .expect("ingest load");
        server.drain();
        let latency = report.latency.expect("completed requests");
        let sample_time =
            Summary::of(&[report.wall_s / report.completed.max(1) as f64]).expect("one value");
        println!(
            "serve.ingest[{label}]: {}/{} completed at {:.1} req/s — latency p50 {}",
            report.completed,
            report.submitted,
            report.completed as f64 / report.wall_s.max(1e-12),
            human_time(latency.p50),
        );
        set.push(&format!("serve.ingest[{label}]: latency"), latency);
        set.push(&format!("serve.ingest[{label}]: sample time"), sample_time);
    }

    // Queue-shed pricing: 8 closed-loop lanes against an admission bound
    // of 4 — sustained 2× overload. `sample time` here is wall seconds
    // per terminal decision (outputs AND busies), so a slow reject path
    // reads as a regression even though sheds never touch an engine.
    {
        let mut registry = ModelRegistry::new();
        let gg = g.clone();
        registry.register(
            "bench",
            shapes.clone(),
            1,
            BatcherConfig { max_batch: 4, max_wait: std::time::Duration::from_millis(1) },
            move |_w| Ok(Engine::interp(gg.clone())),
        );
        let cfg = IngestConfig { queue_depth: 4, ..IngestConfig::default() };
        let mut server = IngestServer::start("127.0.0.1:0", registry, cfg).expect("ingest server");
        let report = drive_load(
            &server.local_addr().to_string(),
            "bench",
            &shapes,
            256,
            8,
            0,
            std::time::Duration::from_secs(30),
            9,
        )
        .expect("ingest load");
        server.drain();
        let sample_time =
            Summary::of(&[report.wall_s / report.submitted.max(1) as f64]).expect("one value");
        println!(
            "serve.ingest[shed 2x]: {} completed / {} shed of {} — {:.1} decisions/s",
            report.completed,
            report.shed,
            report.submitted,
            report.submitted as f64 / report.wall_s.max(1e-12),
        );
        set.push("serve.ingest[shed 2x]: sample time", sample_time);
    }

    if let Some(path) = out_path() {
        set.write(&path).expect("writing bench document");
    }
}
