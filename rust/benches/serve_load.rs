//! Serving load benchmark — the coordinator under a sustained synthetic
//! request stream, reported per stage: queue wait, batch assembly, engine
//! execution, and end-to-end latency, for the serial and the parallel
//! zoo-model engines.
//!
//! Pass `--out BENCH_serve.json` (after `cargo bench -- `) or set
//! `BENCH_OUT` to also write the machine-readable suite document
//! (schema `xenos-bench-v1`) that pins the serving-perf trajectory per PR.

use std::sync::Arc;

use xenos::graph::{GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::runtime::Engine;
use xenos::serve::{
    coordinator::synthetic_requests, BatcherConfig, Coordinator, ServeConfig, ServeReport,
};
use xenos::util::bench::BenchSet;
use xenos::util::human_time;
use xenos::util::stats::Summary;

/// `--out PATH` (after `cargo bench -- `) or the `BENCH_OUT` env var.
fn out_path() -> Option<String> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--out" {
            return args.next();
        }
    }
    std::env::var("BENCH_OUT").ok()
}

/// The small CNN block every serving worker executes.
fn serve_block() -> xenos::Graph {
    let mut b = GraphBuilder::new("serve_block");
    let x = b.input("x", Shape::nchw(1, 16, 16, 16));
    let c1 = b.conv_bn_relu("c1", x, 32, 3, 1, 1);
    let p = b.avgpool("p", c1, 2, 2);
    let f = b.fc("fc", p, 10);
    let s = b.softmax("sm", f);
    b.output(s);
    b.finish()
}

/// Per-sample amortized engine time: each response's `exec_s` covers the
/// whole batch it was served in, so divide by its batch size. This keeps
/// the `exec` entries comparable with pre-batching baselines, where one
/// response was one engine call.
fn per_sample_exec(report: &ServeReport) -> Summary {
    let xs: Vec<f64> = report
        .responses
        .iter()
        .map(|r| r.exec_s / (r.batch_size.max(1) as f64))
        .collect();
    Summary::of(&xs).expect("at least one response")
}

fn main() {
    let mut set = BenchSet::new("serve");
    let g = Arc::new(serve_block());
    let shapes: Vec<Shape> =
        g.input_ids().iter().map(|&i| g.node(i).out.shape.clone()).collect();

    for (label, threads) in [("interp", 1usize), ("par x2", 2)] {
        let cfg = ServeConfig {
            workers: 2,
            engine_threads: threads,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(1),
            },
            ..Default::default()
        };
        let gg = g.clone();
        let report = Coordinator::new(cfg)
            .run(
                move |_w| {
                    Ok(if threads > 1 {
                        let d = presets::tms320c6678();
                        Engine::par_interp(gg.clone(), &d, threads)
                    } else {
                        Engine::interp(gg.clone())
                    })
                },
                synthetic_requests(shapes.clone(), 256, 0.0, 9),
            )
            .expect("serve run");
        let exec = per_sample_exec(&report);
        println!(
            "serve[{label}]: {} requests at {:.1} req/s — latency p50 {}, exec p50 {}, \
             queue p50 {}, assembly p50 {}",
            report.served,
            report.throughput,
            human_time(report.latency.p50),
            human_time(exec.p50),
            human_time(report.queue.p50),
            human_time(report.assembly.p50),
        );
        set.push(&format!("serve[{label}]: latency"), report.latency);
        set.push(&format!("serve[{label}]: exec"), exec);
        set.push(&format!("serve[{label}]: queue"), report.queue);
        set.push(&format!("serve[{label}]: assembly"), report.assembly);
    }

    // Batch-size sweep: the same engine and request stream served at
    // max_batch 1/4/8 — the amortization curve of true batched
    // execution. `sample time` is the inverse throughput (wall seconds
    // per served request, lower = faster), so the gate reads a
    // throughput loss as a regression like any other timing entry.
    for batch in [1usize, 4, 8] {
        let cfg = ServeConfig {
            workers: 2,
            engine_threads: 1,
            batcher: BatcherConfig {
                max_batch: batch,
                max_wait: std::time::Duration::from_millis(1),
            },
            ..Default::default()
        };
        let gg = g.clone();
        let report = Coordinator::new(cfg)
            .run(
                move |_w| Ok(Engine::interp(gg.clone())),
                synthetic_requests(shapes.clone(), 256, 0.0, 9),
            )
            .expect("serve run");
        let exec = per_sample_exec(&report);
        let sample_time =
            Summary::of(&[report.wall_s / report.served.max(1) as f64]).expect("one value");
        println!(
            "serve[batch {batch}]: {} requests at {:.1} req/s (fill {:.2}) — \
             per-sample latency p50 {}, per-sample exec p50 {}",
            report.served,
            report.throughput,
            report.batch_fill,
            human_time(report.latency.p50),
            human_time(exec.p50),
        );
        set.push(&format!("serve[batch {batch}]: per-sample latency"), report.latency);
        set.push(&format!("serve[batch {batch}]: per-sample exec"), exec);
        set.push(&format!("serve[batch {batch}]: sample time"), sample_time);
    }

    if let Some(path) = out_path() {
        set.write(&path).expect("writing bench document");
    }
}
