//! Hot-path microbenchmarks for the performance pass (EXPERIMENTS.md §Perf):
//! the L3 paths that dominate end-to-end runs — the numeric operator
//! library (serving fallback), the serial-vs-parallel plan executor, the
//! cache simulator, the cost model, the optimizer passes, and the serving
//! batcher loop.
//!
//! The `exec:` section is the tentpole comparison: the same graphs run
//! through the serial `Interpreter` and through the `ParInterpreter`
//! (DOS split on a worker pool), with the speedup printed per pair.
//!
//! Pass `--out BENCH_kernels.json` (after `cargo bench -- `) or set
//! `BENCH_OUT` to also write the machine-readable suite document
//! (schema `xenos-bench-v1`) that pins the perf trajectory per PR.

use std::sync::Arc;

use xenos::graph::{models, ConvAttrs, DataLayout, GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::ops::{conv, interp::synthetic_inputs, matmul, Interpreter, ParInterpreter, Tensor};
use xenos::opt;
use xenos::serve::{Batcher, BatcherConfig, Coordinator, ServeConfig};
use xenos::sim::cache::{pointwise_consumer_trace, CacheSim};
use xenos::sim::cost::node_cost;
use xenos::util::bench::{bench, BenchSet};
use xenos::util::rng::Rng;

/// Executor workers used for the parallel arms (the TMS preset's unit
/// count is 8; 4 matches the acceptance comparison and most CI hosts).
const PAR_WORKERS: usize = 4;

/// `--out PATH` (after `cargo bench -- `) or the `BENCH_OUT` env var.
fn out_path() -> Option<String> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--out" {
            return args.next();
        }
    }
    std::env::var("BENCH_OUT").ok()
}

fn main() {
    let mut rng = Rng::new(77);
    let mut set = BenchSet::new("kernels");

    // --- ops: conv kernels (interpreter hot loop) -----------------------
    let x = Tensor::fm(1, 64, 56, 56, rng.vec_uniform(64 * 56 * 56));
    let a3 = ConvAttrs::std(64, 64, 3, 1, 1);
    let w3 = rng.vec_uniform(a3.weight_count() as usize);
    set.bench("ops::conv2d 3x3 64->64 @56", 1, 8, || conv::conv2d(&x, &a3, &w3, &[]).data.len());

    let a1 = ConvAttrs::std(64, 128, 1, 1, 0);
    let w1 = rng.vec_uniform(a1.weight_count() as usize);
    set.bench("ops::conv2d 1x1 64->128 @56 (packed)", 1, 8, || {
        conv::conv2d(&x, &a1, &w1, &[]).data.len()
    });

    let adw = ConvAttrs::depthwise(64, 3, 1, 1);
    let wdw = rng.vec_uniform(adw.weight_count() as usize);
    set.bench("ops::conv2d dw3x3 64 @56", 2, 10, || conv::conv2d(&x, &adw, &wdw, &[]).data.len());

    // --- ops: matmul (packed panel + register tiling) --------------------
    let ma = Tensor::mat(128, 512, rng.vec_uniform(128 * 512));
    let mb = Tensor::mat(512, 512, rng.vec_uniform(512 * 512));
    set.bench("ops::matmul 128x512x512 (packed)", 2, 20, || matmul::matmul(&ma, &mb).data.len());

    // --- tentpole: serial vs parallel plan executor ----------------------
    let device = presets::tms320c6678();

    // 3x3 conv 64->64 @56 — the acceptance-criterion op.
    let conv_graph = Arc::new({
        let mut b = GraphBuilder::new("conv3x3_block");
        let cx = b.input("x", Shape::nchw(1, 64, 56, 56));
        let c = b.conv("c", cx, 64, 3, 1, 1);
        b.output(c);
        b.finish()
    });
    let conv_inputs = synthetic_inputs(&conv_graph, 21);
    let conv_ser = Interpreter::new(&conv_graph);
    let s_conv_ser =
        bench("exec: conv3x3 64->64 @56 serial", 1, 10, || conv_ser.run(&conv_inputs).len());
    let conv_par = ParInterpreter::new(conv_graph.clone(), &device, PAR_WORKERS);
    let s_conv_par = bench("exec: conv3x3 64->64 @56 par x4", 1, 10, || {
        conv_par.run(&conv_inputs).len()
    });
    println!(
        "  -> conv split speedup x{:.2} ({} workers effective)",
        s_conv_ser.mean / s_conv_par.mean,
        conv_par.workers()
    );
    set.push("exec: conv3x3 64->64 @56 serial", s_conv_ser);
    set.push("exec: conv3x3 64->64 @56 par x4", s_conv_par);

    // Weighted FC 2048->2048 — the packed panel under a column split.
    let fc_graph = Arc::new({
        let mut b = GraphBuilder::new("fc2048");
        let fx = b.input("x", Shape::mat(8, 2048));
        let f = b.fc("fc", fx, 2048);
        b.output(f);
        b.finish()
    });
    let fc_inputs = synthetic_inputs(&fc_graph, 22);
    let fc_ser = Interpreter::new(&fc_graph);
    let s_fc_ser = bench("exec: fc 8x2048x2048 serial", 1, 10, || fc_ser.run(&fc_inputs).len());
    let fc_par = ParInterpreter::new(fc_graph.clone(), &device, PAR_WORKERS);
    let s_fc_par =
        bench("exec: fc 8x2048x2048 par x4", 1, 10, || fc_par.run(&fc_inputs).len());
    println!("  -> fc split speedup x{:.2}", s_fc_ser.mean / s_fc_par.mean);
    set.push("exec: fc 8x2048x2048 serial", s_fc_ser);
    set.push("exec: fc 8x2048x2048 par x4", s_fc_par);

    // End-to-end MobileNet inference — the acceptance-criterion model.
    let mn = Arc::new(models::mobilenet());
    let mn_inputs = synthetic_inputs(&mn, 5);
    let mn_ser = Interpreter::new(&mn);
    let s_mn_ser =
        bench("exec: mobilenet e2e serial", 1, 5, || mn_ser.run(&mn_inputs).len());
    let mn_par = ParInterpreter::new(mn.clone(), &device, PAR_WORKERS);
    let s_mn_par =
        bench("exec: mobilenet e2e par x4", 1, 5, || mn_par.run(&mn_inputs).len());
    let (reused, allocated) = mn_par.arena_stats();
    println!(
        "  -> mobilenet e2e speedup x{:.2} | arena: {} buffers reused, {} allocated",
        s_mn_ser.mean / s_mn_par.mean,
        reused,
        allocated
    );
    set.push("exec: mobilenet e2e serial", s_mn_ser);
    set.push("exec: mobilenet e2e par x4", s_mn_par);

    // --- full interpreter on the AOT-equivalent block --------------------
    let small = {
        let mut b = GraphBuilder::new("block");
        let bx = b.input("x", Shape::nchw(1, 32, 16, 16));
        let c1 = b.conv_bn_relu("c1", bx, 64, 1, 1, 0);
        let c2 = b.conv_bn_relu("c2", c1, 64, 1, 1, 0);
        let p = b.avgpool("p", c2, 2, 2);
        let f = b.fc("fc", p, 10);
        let s = b.softmax("sm", f);
        b.output(s);
        b.finish()
    };
    let interp = Interpreter::new(&small);
    let inputs = synthetic_inputs(&small, 3);
    set.bench("interp: serve-block forward", 2, 50, || interp.run(&inputs).len());

    // --- cache simulator --------------------------------------------------
    let trace = pointwise_consumer_trace(DataLayout::Chw, 64, 112, 112);
    set.bench("cache-sim 800K strided accesses", 1, 10, || {
        let mut c = CacheSim::new(32 * 1024, 64, 4);
        c.run(trace.iter().copied());
        c.misses
    });

    // --- optimizer + cost model -------------------------------------------
    let g = models::resnet101();
    let d = presets::tms320c6678();
    set.bench("opt::auto resnet101 (418 nodes)", 1, 10, || opt::auto(&g, &d).fused);
    let o = opt::auto(&g, &d);
    set.bench("cost-model full resnet101 sweep", 2, 50, || {
        o.graph
            .nodes
            .iter()
            .map(|n| node_cost(&o.graph, n, o.plan.node(n.id), &d).total_s)
            .sum::<f64>()
    });

    // --- serving: batcher + coordinator round trip -------------------------
    let serve_graph = Arc::new({
        let mut b = GraphBuilder::new("tiny");
        let sx = b.input("x", Shape::nchw(1, 4, 8, 8));
        let r = b.relu("r", sx);
        b.output(r);
        b.finish()
    });
    set.bench("coordinator: 128 requests through 2 workers", 1, 10, || {
        let sg = serve_graph.clone();
        Coordinator::new(ServeConfig {
            workers: 2,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_micros(200),
            },
            ..Default::default()
        })
        .run(
            move |_| Ok(xenos::runtime::Engine::interp(sg.clone())),
            xenos::serve::coordinator::synthetic_requests(
                vec![Shape::nchw(1, 4, 8, 8)],
                128,
                0.0,
                5,
            ),
        )
        .map(|r| r.served)
        .expect("serve")
    });

    // --- batcher in isolation ----------------------------------------------
    set.bench("batcher: form 64 batches of 8", 2, 20, || {
        let (tx, rx) = std::sync::mpsc::channel();
        for id in 0..512u64 {
            tx.send(xenos::serve::Request {
                id,
                inputs: vec![],
                submitted: std::time::Instant::now(),
            })
            .expect("send");
        }
        drop(tx);
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: std::time::Duration::from_micros(100),
        });
        let mut n = 0;
        while let Some(batch) = b.next_batch(&rx) {
            n += batch.len();
        }
        n
    });

    if let Some(path) = out_path() {
        set.write(&path).expect("writing bench document");
    }
}
