//! Bench target for paper Table 2: the wall-clock cost of the automatic
//! optimization across all seven benchmarks, per-model.

use xenos::graph::models;
use xenos::hw::presets;
use xenos::util::bench::bench;

fn main() {
    xenos::exp::run("table2").expect("registered").print();

    let d = presets::tms320c6678();
    for name in models::PAPER_BENCHMARKS {
        let g = models::by_name(name).expect("zoo model");
        bench(&format!("auto-optimize {name}"), 2, 15, || xenos::opt::auto(&g, &d).fused);
    }
}
