//! Bench target for paper Figure 10: ZCU102 FPGA resource cost
//! (DSP/LUT/FF) for MobileNet and SqueezeNet across the ablation arms.

use xenos::graph::models;
use xenos::hw::presets;
use xenos::opt::OptLevel;
use xenos::sim::run_level;
use xenos::util::bench::bench;

fn main() {
    xenos::exp::run("fig10").expect("registered").print();

    let d = presets::zcu102();
    let g = models::squeezenet();
    bench("simulate squeezenet on zcu102 (full)", 2, 20, || {
        run_level(&g, &d, OptLevel::Full).1.fpga.dsp
    });
}
