"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

The CORE correctness signal of the build-time layer — run by
``make test`` before anything is lowered.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import cbr, cbra, fc_split
from compile.kernels import ref


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


@pytest.fixture
def keys():
    k = jax.random.PRNGKey(7)
    return jax.random.split(k, 4)


class TestCbr:
    def test_matches_ref(self, keys):
        x = rand(keys[0], (1, 8, 8, 16))
        w = rand(keys[1], (16, 64), scale=0.25)
        s = rand(keys[2], (64,), scale=0.1) + 1.0
        b = rand(keys[3], (64,), scale=0.1)
        np.testing.assert_allclose(
            cbr(x, w, s, b), ref.cbr_ref(x, w, s, b), rtol=1e-5, atol=1e-5
        )

    def test_relu_clamps_negative(self, keys):
        x = rand(keys[0], (1, 4, 4, 8))
        w = rand(keys[1], (8, 32), scale=0.5)
        s = jnp.ones(32)
        b = jnp.full((32,), -100.0)  # force everything negative
        out = cbr(x, w, s, b)
        assert float(jnp.max(out)) == 0.0

    def test_single_channel_block(self, keys):
        # Cout smaller than BLOCK_C exercises the clamped block path.
        x = rand(keys[0], (1, 4, 4, 8))
        w = rand(keys[1], (8, 16), scale=0.5)
        s = jnp.ones(16)
        b = jnp.zeros(16)
        np.testing.assert_allclose(
            cbr(x, w, s, b), ref.cbr_ref(x, w, s, b), rtol=1e-5, atol=1e-5
        )

    def test_wide_channels(self, keys):
        x = rand(keys[0], (1, 4, 4, 32))
        w = rand(keys[1], (32, 128), scale=0.2)
        s = jnp.ones(128) * 0.9
        b = jnp.zeros(128)
        np.testing.assert_allclose(
            cbr(x, w, s, b), ref.cbr_ref(x, w, s, b), rtol=1e-5, atol=1e-5
        )


class TestCbra:
    def test_matches_ref(self, keys):
        x = rand(keys[0], (1, 8, 8, 16))
        w = rand(keys[1], (16, 32), scale=0.25)
        s = rand(keys[2], (32,), scale=0.1) + 1.0
        b = rand(keys[3], (32,), scale=0.1)
        np.testing.assert_allclose(
            cbra(x, w, s, b), ref.cbra_ref(x, w, s, b), rtol=1e-5, atol=1e-5
        )

    def test_output_is_half_resolution(self, keys):
        x = rand(keys[0], (1, 16, 16, 8))
        w = rand(keys[1], (8, 32), scale=0.5)
        out = cbra(x, w, jnp.ones(32), jnp.zeros(32))
        assert out.shape == (1, 8, 8, 32)

    def test_constant_input_pools_to_same(self, keys):
        # A constant map stays constant through 1x1 conv + avg pool.
        x = jnp.ones((1, 8, 8, 4))
        w = rand(keys[1], (4, 32), scale=0.5)
        out = cbra(x, w, jnp.ones(32), jnp.zeros(32))
        expect = ref.cbr_ref(x, w, jnp.ones(32), jnp.zeros(32))[0, 0, 0]
        np.testing.assert_allclose(out[0, 2, 3], expect, rtol=1e-5, atol=1e-6)

    def test_linked_equals_unlinked_dataflow(self, keys):
        # The reproduction's core semantic claim, at the kernel level:
        # the linked dataflow computes exactly the unlinked result.
        x = rand(keys[0], (1, 12, 12, 24))
        w = rand(keys[1], (24, 32), scale=0.3)
        s = rand(keys[2], (32,), scale=0.05) + 1.0
        b = rand(keys[3], (32,), scale=0.05)
        linked = cbra(x, w, s, b)
        unlinked = ref.avgpool2x2_ref(ref.cbr_ref(x, w, s, b))
        np.testing.assert_allclose(linked, unlinked, rtol=1e-5, atol=1e-5)


class TestFcSplit:
    def test_matches_ref(self, keys):
        x = rand(keys[0], (4, 64))
        w = rand(keys[1], (64, 256), scale=0.2)
        b = rand(keys[2], (256,), scale=0.1)
        np.testing.assert_allclose(
            fc_split(x, w, b), ref.fc_ref(x, w, b), rtol=1e-5, atol=1e-5
        )

    def test_split_chunks_join_seamlessly(self, keys):
        # Paper Eq. 1: y1/y2 computed on separate chunks join with no
        # transformation. Compare against an explicit two-chunk compute.
        x = rand(keys[0], (1, 32))
        w = rand(keys[1], (32, 256), scale=0.2)
        b = rand(keys[2], (256,), scale=0.1)
        y = fc_split(x, w, b)
        y1 = ref.fc_ref(x, w[:, :128], b[:128])
        y2 = ref.fc_ref(x, w[:, 128:], b[128:])
        np.testing.assert_allclose(
            y, jnp.concatenate([y1, y2], axis=1), rtol=1e-5, atol=1e-5
        )

    def test_small_n(self, keys):
        x = rand(keys[0], (2, 16))
        w = rand(keys[1], (16, 10), scale=0.3)
        b = jnp.zeros(10)
        np.testing.assert_allclose(
            fc_split(x, w, b), ref.fc_ref(x, w, b), rtol=1e-5, atol=1e-5
        )
