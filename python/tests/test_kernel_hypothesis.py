"""Hypothesis sweeps over the Pallas kernels' shapes and dtypes.

Randomized shape/dtype coverage against the pure-jnp oracle, per the
session's L1 testing requirement.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import cbr, cbra, fc_split
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)

dtypes = st.sampled_from([jnp.float32, jnp.bfloat16])


def tol_for(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-4, atol=1e-4
    )


def make(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.3).astype(dtype)


@settings(**SETTINGS)
@given(
    h=st.integers(2, 10).map(lambda v: 2 * v),
    w=st.integers(2, 10).map(lambda v: 2 * v),
    cin=st.sampled_from([4, 8, 16, 48]),
    cout_blocks=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
    dtype=dtypes,
)
def test_cbr_shapes(h, w, cin, cout_blocks, seed, dtype):
    cout = 32 * cout_blocks
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = make(k[0], (1, h, w, cin), dtype)
    wt = make(k[1], (cin, cout), dtype)
    s = (jax.random.uniform(k[2], (cout,)) + 0.5).astype(dtype)
    b = make(k[3], (cout,), dtype)
    got = np.asarray(cbr(x, wt, s, b), dtype=np.float32)
    want = np.asarray(ref.cbr_ref(x, wt, s, b), dtype=np.float32)
    np.testing.assert_allclose(got, want, **tol_for(dtype))


@settings(**SETTINGS)
@given(
    h=st.integers(1, 8).map(lambda v: 2 * v),
    w=st.integers(1, 8).map(lambda v: 2 * v),
    cin=st.sampled_from([4, 16, 32]),
    cout_blocks=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    dtype=dtypes,
)
def test_cbra_shapes(h, w, cin, cout_blocks, seed, dtype):
    cout = 32 * cout_blocks
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = make(k[0], (1, h, w, cin), dtype)
    wt = make(k[1], (cin, cout), dtype)
    s = (jax.random.uniform(k[2], (cout,)) + 0.5).astype(dtype)
    b = make(k[3], (cout,), dtype)
    got = np.asarray(cbra(x, wt, s, b), dtype=np.float32)
    want = np.asarray(ref.cbra_ref(x, wt, s, b), dtype=np.float32)
    np.testing.assert_allclose(got, want, **tol_for(dtype))


@settings(**SETTINGS)
@given(
    m=st.integers(1, 8),
    kdim=st.sampled_from([8, 32, 64, 200]),
    n_blocks=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
    dtype=dtypes,
)
def test_fc_split_shapes(m, kdim, n_blocks, seed, dtype):
    n = 128 * n_blocks
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = make(k[0], (m, kdim), dtype)
    wt = make(k[1], (kdim, n), dtype)
    b = make(k[2], (n,), dtype)
    got = np.asarray(fc_split(x, wt, b), dtype=np.float32)
    want = np.asarray(ref.fc_ref(x, wt, b), dtype=np.float32)
    np.testing.assert_allclose(got, want, **tol_for(dtype))
