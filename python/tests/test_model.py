"""L2 model correctness: linked vs vanilla variants, shapes and lowering."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_variants_agree():
    """The dataflow-optimized model must compute the vanilla result."""
    x = jax.random.normal(jax.random.PRNGKey(3), model.INPUT_SHAPE)
    (v,) = model.model_vanilla(x)
    (l,) = model.model_linked(x)
    np.testing.assert_allclose(np.asarray(v), np.asarray(l), rtol=1e-5, atol=1e-6)


def test_output_is_distribution():
    x = jax.random.normal(jax.random.PRNGKey(4), model.INPUT_SHAPE)
    (probs,) = model.model_linked(x)
    assert probs.shape == (1, model.CLASSES)
    np.testing.assert_allclose(float(jnp.sum(probs)), 1.0, rtol=1e-5)
    assert float(jnp.min(probs)) >= 0.0


def test_params_deterministic():
    a = model.make_params()
    b = model.make_params()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_lowering_produces_hlo_text():
    for name in ("vanilla", "linked", "smoke"):
        text, manifest = aot.lower_variant(name)
        assert "HloModule" in text, name
        assert f"variant={name}" in manifest
        # return_tuple=True — the Rust side unwraps a 1-tuple.
        assert "ROOT" in text


def test_smoke_fn_matches_xla_example():
    x = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    y = jnp.ones((2, 2))
    (out,) = model.smoke_fn(x, y)
    np.testing.assert_array_equal(np.asarray(out), [[5.0, 5.0], [9.0, 9.0]])


def test_manifest_shape_tags():
    specs = model.VARIANTS["linked"][1]
    assert aot.shape_tag(specs[0]) == "1x16x16x32:float32"
