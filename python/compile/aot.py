"""AOT lowering: JAX -> HLO **text** artifacts for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that the xla_extension 0.5.1
bundled with the ``xla`` crate rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py and README.md.

Usage::

    python -m compile.aot --outdir ../artifacts

Writes one ``<variant>.hlo.txt`` per entry in ``model.VARIANTS`` plus a
``manifest.txt`` describing each artifact's inputs (parsed by the Rust
runtime)::

    variant=linked inputs=1x16x16x32:f32 outputs=1x10:f32
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_tag(s) -> str:
    """``1x16x16x32:f32`` style tag for the manifest."""
    dims = "x".join(str(d) for d in s.shape)
    return f"{dims}:{s.dtype}"


def lower_variant(name: str):
    """Lower one model variant; returns (hlo_text, manifest_line)."""
    fn, specs = model.VARIANTS[name]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    outs = jax.eval_shape(fn, *specs)
    ins = ",".join(shape_tag(s) for s in specs)
    out_tags = ",".join(shape_tag(s) for s in outs)
    manifest = f"variant={name} inputs={ins} outputs={out_tags}"
    return text, manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=",".join(model.VARIANTS),
        help="comma-separated subset of variants to lower",
    )
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest_lines = []
    for name in args.variants.split(","):
        text, manifest = lower_variant(name)
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(manifest)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.outdir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
