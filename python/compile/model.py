"""L2 JAX model: a MobileNet-head inference block in two dataflow variants.

This is the compute the Rust serving engine executes through PJRT. It is
the paper's Figure 5 example made concrete — ``CBR -> CBR(+AvgPool) ->
FC -> softmax`` — built twice:

* ``model_vanilla``: plain jnp ops, materializing every intermediate (the
  unlinked dataflow a generic compiler emits).
* ``model_linked``: the L1 Pallas kernels — fused CBR, *linked* CBRA (the
  pre-pool map never reaches HBM) and the K-split FC.

Both variants bake the same deterministically generated parameters as
constants, so the Rust runtime can assert their outputs are identical and
benchmark the dataflow difference with everything else equal.

Shapes (edge-typical): input ``[1, 16, 16, 32]`` NHWC -> logits ``[1, 10]``.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import cbr, cbra, fc_split
from .kernels import ref

# Model dimensions.
IN_H = IN_W = 16
IN_C = 32
MID_C = 64
OUT_C = 64
FC_IN = (IN_H // 2) * (IN_W // 2) * OUT_C  # 4096
CLASSES = 10

INPUT_SHAPE = (1, IN_H, IN_W, IN_C)

# Deterministic parameters (seeded; both variants share them).
_PARAM_SEED = 20230


def make_params():
    """Generate the model's parameters deterministically."""
    rng = np.random.RandomState(_PARAM_SEED)

    def glorot(shape, fan_in):
        return (rng.uniform(-1, 1, size=shape) / np.sqrt(fan_in)).astype(
            np.float32
        )

    return {
        "w1": glorot((IN_C, MID_C), IN_C),
        "s1": rng.uniform(0.5, 1.5, MID_C).astype(np.float32),
        "b1": rng.uniform(-0.1, 0.1, MID_C).astype(np.float32),
        "w2": glorot((MID_C, OUT_C), MID_C),
        "s2": rng.uniform(0.5, 1.5, OUT_C).astype(np.float32),
        "b2": rng.uniform(-0.1, 0.1, OUT_C).astype(np.float32),
        "wf": glorot((FC_IN, CLASSES), FC_IN),
        "bf": rng.uniform(-0.05, 0.05, CLASSES).astype(np.float32),
    }


_P = {k: jnp.asarray(v) for k, v in make_params().items()}


def model_vanilla(x):
    """Unlinked dataflow: every op standalone, intermediates materialized."""
    y = ref.cbr_ref(x, _P["w1"], _P["s1"], _P["b1"])
    y = ref.cbr_ref(y, _P["w2"], _P["s2"], _P["b2"])
    y = ref.avgpool2x2_ref(y)
    y = y.reshape(1, FC_IN)
    y = ref.fc_ref(y, _P["wf"], _P["bf"])
    return (ref.softmax_ref(y),)


def model_linked(x):
    """Xenos dataflow: fused CBR, linked CBRA, K-split FC (L1 kernels)."""
    y = cbr(x, _P["w1"], _P["s1"], _P["b1"])
    y = cbra(y, _P["w2"], _P["s2"], _P["b2"])
    y = y.reshape(1, FC_IN)
    y = fc_split(y, _P["wf"], _P["bf"])
    return (ref.softmax_ref(y),)


def smoke_fn(x, y):
    """Tiny matmul artifact used by the Rust runtime smoke tests (mirrors
    /opt/xla-example: ``matmul(x, y) + 2`` over f32[2,2])."""
    return (jnp.matmul(x, y) + 2.0,)


VARIANTS = {
    "vanilla": (model_vanilla, [jax.ShapeDtypeStruct(INPUT_SHAPE, jnp.float32)]),
    "linked": (model_linked, [jax.ShapeDtypeStruct(INPUT_SHAPE, jnp.float32)]),
    "smoke": (
        smoke_fn,
        [
            jax.ShapeDtypeStruct((2, 2), jnp.float32),
            jax.ShapeDtypeStruct((2, 2), jnp.float32),
        ],
    ),
}
