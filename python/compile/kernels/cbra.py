"""L1 Pallas kernel: linked CBR + AvgPool2x2 (the paper's ``x.cbra``).

This is the **vertical optimization** (operator linking, paper §4.1)
re-thought for the TPU memory system: instead of materializing the full
conv output to HBM and re-reading it in pooling-window order (the
layout-mismatched dataflow of Figure 2), the kernel computes the conv on a
block of pooling windows and reduces each window *while it is still in
VMEM*. The pre-pool feature map never exists in HBM — the strongest
possible form of "the producer writes in the order the consumer reads".

The grid is (window-row blocks × output-channel blocks): channel blocks
keep the weight tile VMEM-resident (the DOS split, as in ``cbr.py``), and
window-row blocks bound the activation tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output channels per grid step.
BLOCK_C = 32
# Pooling-window rows per grid step.
BLOCK_WR = 4


def _cbra_kernel(x_ref, w_ref, scale_ref, shift_ref, o_ref):
    """One grid step: BLOCK_WR window-rows × one output-channel block.

    ``x_ref`` arrives as ``[WR, 2, W, Cin]`` — window-row-major with the
    2 in-window rows adjacent (the linked layout). The kernel convolves,
    applies Bn+ReLU, and reduces each 2x2 window in-register.
    """
    x = x_ref[...]  # [WR, 2, W, Cin]
    wr, two, wd, cin = x.shape
    w = w_ref[...]  # [Cin, BC]
    y = jnp.dot(x.reshape(wr * two * wd, cin), w,
                preferred_element_type=jnp.float32)
    y = y * scale_ref[...] + shift_ref[...]
    y = jnp.maximum(y, 0.0)
    # Reduce each 2x2 pooling window while resident.
    y = y.reshape(wr, two, wd // 2, 2, -1)
    o_ref[...] = y.mean(axis=(1, 3)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def cbra(x, w, scale, shift):
    """Linked pointwise Conv+Bn+ReLU+AvgPool2x2.

    Args:
      x: ``[N, H, W, Cin]`` with even ``H``/``W``; ``N`` must be 1 (edge
        inference batch, as in the paper's pipeline).
      w: ``[Cin, Cout]``.
      scale, shift: ``[Cout]``.

    Returns:
      ``[N, H/2, W/2, Cout]``.
    """
    n, h, wd, cin = x.shape
    assert n == 1, "edge inference kernel: batch 1"
    assert h % 2 == 0 and wd % 2 == 0
    cout = w.shape[1]
    block_c = min(BLOCK_C, cout)
    assert cout % block_c == 0
    wrows = h // 2
    # Largest window-row block <= BLOCK_WR that tiles wrows exactly.
    block_wr = max(d for d in range(1, min(BLOCK_WR, wrows) + 1) if wrows % d == 0)

    # Window-row-major view: [wrows, 2, W, Cin] — in-window rows adjacent.
    x4 = x.reshape(wrows, 2, wd, cin)

    out = pl.pallas_call(
        _cbra_kernel,
        grid=(wrows // block_wr, cout // block_c),
        in_specs=[
            pl.BlockSpec((block_wr, 2, wd, cin), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((cin, block_c), lambda i, j: (0, j)),
            pl.BlockSpec((block_c,), lambda i, j: (j,)),
            pl.BlockSpec((block_c,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec(
            (block_wr, wd // 2, block_c), lambda i, j: (i, 0, j)
        ),
        out_shape=jax.ShapeDtypeStruct((wrows, wd // 2, cout), x.dtype),
        interpret=True,
    )(x4, w, scale, shift)
    return out.reshape(1, wrows, wd // 2, cout)
