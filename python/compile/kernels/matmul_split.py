"""L1 Pallas kernel: K-split fully-connected layer (the paper's §4.2.2
operator-parameter split, Equation 1).

The weight matrix is split along the output dimension into chunks sized to
stay VMEM-resident (the private-L2 analogue); the grid walks the chunks and
each step computes ``y_i = W_i x + B_i``. The outputs are "automatically
joined together afterwards, without performing any data layout
transformation operators" — here literally, by the output BlockSpec.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output features per grid step (one W_i/B_i chunk).
BLOCK_N = 128


def _fc_kernel(x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...]  # [M, K]
    w = w_ref[...]  # [K, BLOCK_N]
    o_ref[...] = (
        jnp.dot(x, w, preferred_element_type=jnp.float32) + b_ref[...]
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def fc_split(x, w, b):
    """K-split fully-connected: ``x [M,K] @ w [K,N] + b [N]``.

    ``N`` must be a multiple of ``BLOCK_N`` or smaller than it.
    """
    m, k = x.shape
    n = w.shape[1]
    block_n = min(BLOCK_N, n)
    assert n % block_n == 0, f"N {n} not a multiple of {block_n}"

    return pl.pallas_call(
        _fc_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k, block_n), lambda j: (0, j)),
            pl.BlockSpec((block_n,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)
