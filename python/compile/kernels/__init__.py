"""L1 Pallas kernels for the Xenos reproduction (build-time only)."""

from .cbr import cbr
from .cbra import cbra
from .matmul_split import fc_split
