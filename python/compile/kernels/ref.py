"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every kernel in this package is validated against these references by
``python/tests/test_kernel.py`` (exact shapes) and by hypothesis sweeps
(randomized shapes/dtypes). The references are deliberately written with
plain ``jnp`` ops so they lower to stock XLA HLO — they double as the
*vanilla* (unlinked, materializing) variant of the model in ``model.py``.
"""

import jax.numpy as jnp


def cbr_ref(x, w, scale, shift):
    """Pointwise Conv + BatchNorm + ReLU reference.

    Args:
      x: ``[N, H, W, Cin]`` input feature map (NHWC).
      w: ``[Cin, Cout]`` pointwise kernel.
      scale: ``[Cout]`` folded Bn scale.
      shift: ``[Cout]`` folded Bn shift.

    Returns:
      ``[N, H, W, Cout]``.
    """
    y = jnp.einsum("nhwc,cd->nhwd", x, w)
    y = y * scale + shift
    return jnp.maximum(y, 0.0)


def avgpool2x2_ref(x):
    """Non-overlapping 2x2 average pooling on NHWC."""
    n, h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0, "avgpool2x2 needs even H/W"
    x = x.reshape(n, h // 2, 2, w // 2, 2, c)
    return x.mean(axis=(2, 4))


def cbra_ref(x, w, scale, shift):
    """Linked CBR + AvgPool2x2 reference: the *unlinked* dataflow, which
    materializes the full pre-pool map before reducing it."""
    return avgpool2x2_ref(cbr_ref(x, w, scale, shift))


def fc_ref(x, w, b):
    """Fully-connected reference: ``x [M, K] @ w [K, N] + b [N]``."""
    return x @ w + b


def softmax_ref(x):
    """Numerically stable softmax over the last axis."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)
