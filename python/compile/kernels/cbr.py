"""L1 Pallas kernel: fused pointwise Conv + Bn + ReLU (the paper's ``x.cbr``).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper splits
operator parameters into each DSP unit's private L2 (§4.2.2, K-dim first).
On TPU the analogue is the grid/BlockSpec schedule below: the kernel is
gridded over **output-channel blocks**, so each grid step holds only a
``[Cin, BLOCK_C]`` weight tile in VMEM — the private-memory residency the
DOS split buys on the DSP — while the input tile streams once per step.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret-mode lowers to plain HLO the Rust runtime can
execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output channels per grid step — the VMEM-resident weight tile width.
BLOCK_C = 32


def _cbr_kernel(x_ref, w_ref, scale_ref, shift_ref, o_ref):
    """One grid step: all pixels x one output-channel block."""
    x = x_ref[...]  # [P, Cin] pixels-major (linked HWC order)
    w = w_ref[...]  # [Cin, BLOCK_C]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    y = y * scale_ref[...] + shift_ref[...]
    o_ref[...] = jnp.maximum(y, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def cbr(x, w, scale, shift):
    """Fused pointwise Conv+Bn+ReLU.

    Args:
      x: ``[N, H, W, Cin]`` NHWC feature map.
      w: ``[Cin, Cout]``; ``Cout`` must be a multiple of ``BLOCK_C`` or
        smaller than it.
      scale, shift: ``[Cout]`` folded Bn affine.

    Returns:
      ``[N, H, W, Cout]``.
    """
    n, h, wd, cin = x.shape
    cout = w.shape[1]
    block_c = min(BLOCK_C, cout)
    assert cout % block_c == 0, f"Cout {cout} not a multiple of {block_c}"
    pixels = n * h * wd

    # Pixels-major view: the linked (HWC) read order — sequential streams.
    x2 = x.reshape(pixels, cin)

    out = pl.pallas_call(
        _cbr_kernel,
        grid=(cout // block_c,),
        in_specs=[
            # The whole pixel block is re-streamed per channel block...
            pl.BlockSpec((pixels, cin), lambda j: (0, 0)),
            # ...while only a BLOCK_C-wide weight tile is resident.
            pl.BlockSpec((cin, block_c), lambda j: (0, j)),
            pl.BlockSpec((block_c,), lambda j: (j,)),
            pl.BlockSpec((block_c,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((pixels, block_c), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((pixels, cout), x.dtype),
        interpret=True,
    )(x2, w, scale, shift)
    return out.reshape(n, h, wd, cout)
