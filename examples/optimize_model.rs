//! Deep-dive into the optimizer: build a custom graph with the public
//! builder API, walk it through fusion → linking → DOS, and inspect every
//! decision the automatic pipeline makes (paper §4).
//!
//! ```bash
//! cargo run --release --offline --example optimize_model
//! ```

use xenos::graph::{GraphBuilder, Shape};
use xenos::hw::presets;
use xenos::opt::{self, dos, fusion, linking};
use xenos::sim::Simulator;

fn main() {
    // A custom depthwise-separable block ending in pooling — the exact
    // structure of the paper's Figure 5 example.
    let mut b = GraphBuilder::new("custom_block");
    let x = b.input("input", Shape::nchw(1, 64, 56, 56));
    let dw = b.dw_bn_relu("ds/dwise", x, 3, 1, 1);
    let pw = b.conv_bn_relu("ds/pwise", dw, 128, 1, 1, 0);
    let pool = b.avgpool("pool", pw, 2, 2);
    let head = b.conv_bn_relu("head", pool, 256, 1, 1, 0);
    let gp = b.global_pool("gap", head);
    let logits = b.fc("fc", gp, 100);
    b.output(logits);
    let graph = b.finish();
    println!("built graph:\n{}", graph.dump());

    // Stage 1 — operator fusion (preprocessing, paper §3).
    let (fused, n_fused) = fusion::fuse_cbr(&graph);
    println!("fusion: {n_fused} Conv+Bn+Relu triples -> CBR\n{}", fused.dump());

    // Stage 2 — vertical optimization: operator linking (paper §4.1).
    let linked = linking::link(&fused);
    println!("linking applied {} dataflow rewrites:", linked.records.len());
    for r in &linked.records {
        println!(
            "   [{:<28}] {} now writes {} for {}",
            r.pattern,
            r.producer,
            r.layout.tag(),
            r.consumer
        );
    }

    // Stage 3 — horizontal optimization: DSP-aware operator split (§4.2).
    let device = presets::tms320c6678();
    let plan = dos::plan_graph(&linked.graph, &device, opt::OptLevel::Full);
    println!("\nDOS plan on {} ({} DSP units):", device.name, device.dsp_units);
    for node in &linked.graph.nodes {
        let p = plan.node(node.id);
        if p.units > 1 || p.param_split.is_some() {
            println!(
                "   {:<12} units={} partition={:?} split={:?} fits_l2={}",
                node.name, p.units, p.partition, p.param_split, p.params_fit_l2
            );
        }
    }

    // Price the result.
    let sim = Simulator::new(device);
    let report = sim.simulate(&linked.graph, &plan);
    println!(
        "\npredicted inference time: {} (DDR {} / peak SRAM {})",
        xenos::util::human_time(report.total_s),
        xenos::util::human_bytes(report.ddr_bytes),
        xenos::util::human_bytes(report.peak_sram)
    );

    // And verify semantics end-to-end.
    let a = xenos::ops::Interpreter::new(&graph).run_synthetic(1);
    let bb = xenos::ops::Interpreter::new(&linked.graph).run_synthetic(1);
    assert_eq!(a[0].data, bb[0].data, "optimization must preserve numerics");
    println!("numerics preserved bit-exactly. optimize_model OK");
}
