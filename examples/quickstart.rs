//! Quickstart: optimize a model for an edge device and compare the three
//! deployment arms — the 60-second tour of the public API.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use xenos::graph::models;
use xenos::hw::presets;
use xenos::opt::{self, OptLevel};
use xenos::sim::run_level;
use xenos::util::human_time;

fn main() {
    // 1. Pick a model from the zoo and a device preset.
    let model = models::mobilenet();
    let device = presets::tms320c6678();
    println!(
        "model {}: {} nodes, {:.0} MMACs",
        model.name,
        model.len(),
        model.total_macs() as f64 / 1e6
    );

    // 2. Run the automatic dataflow-centric optimization (paper §4.4).
    let optimized = opt::auto(&model, &device);
    println!(
        "auto-optimized in {} — {} CBR fusions, {} operator links, peak {} DSP units",
        human_time(optimized.elapsed.as_secs_f64()),
        optimized.fused,
        optimized.links.len(),
        optimized.plan.peak_units()
    );
    for link in optimized.links.iter().take(5) {
        println!("   link [{:<26}] {} -> {}", link.pattern, link.producer, link.consumer);
    }

    // 3. Simulate the three Fig.-7 arms.
    println!("\ninference time on {} (simulated):", device.name);
    for level in [OptLevel::Vanilla, OptLevel::HoOnly, OptLevel::Full] {
        let (_, report) = run_level(&model, &device, level);
        println!("   {:<14} {}", level.label(), human_time(report.total_s));
    }

    // 4. Numerical guarantee: the optimized graph computes the same thing.
    let base = xenos::ops::Interpreter::new(&model).run_synthetic(42);
    let opt_out = xenos::ops::Interpreter::new(&optimized.graph).run_synthetic(42);
    let diff = base[0].max_abs_diff(&opt_out[0]);
    println!("\nmax |vanilla - optimized| on random input: {diff:e} (must be 0)");
    assert_eq!(diff, 0.0);
    println!("quickstart OK");
}
