//! d-Xenos walkthrough: distribute a large model across a simulated edge
//! cluster, enumerate partition schemes (Algorithm 1), and demonstrate the
//! real ring-all-reduce collective.
//!
//! ```bash
//! cargo run --release --offline --example distributed_inference
//! ```

use xenos::dist::{enumerate_schemes, ring, simulate_dxenos, PartitionScheme, SyncMode};
use xenos::graph::models;
use xenos::hw::presets;
use xenos::util::human_time;

fn main() {
    let device = presets::tms320c6678();
    let p = 4;

    // 1. A model the paper calls out as too big for one device (§5).
    let model = models::resnet101();
    println!(
        "model {}: {:.1} GMACs, {} of parameters",
        model.name,
        model.total_macs() as f64 / 1e9,
        xenos::util::human_bytes(model.total_param_bytes())
    );

    // 2. Algorithm 1: enumerate partition schemes, profile, pick the best.
    let (best, reports) = enumerate_schemes(&model, &device, p, SyncMode::Ring);
    println!("\nAlgorithm 1 profiling on {p}x {}:", device.name);
    for r in &reports {
        println!(
            "   {:<5} {:>10}  (compute {} + sync {})",
            r.scheme.label(),
            human_time(r.total_s),
            human_time(r.compute_s),
            human_time(r.sync_s)
        );
    }
    println!("   -> best scheme: {} (the paper's Ring-Mix)", best.label());

    // 3. Ring vs parameter-server synchronization (paper takeaway 1).
    let ring_mix = simulate_dxenos(&model, &device, p, PartitionScheme::Mix, SyncMode::Ring);
    let ps_mix = simulate_dxenos(&model, &device, p, PartitionScheme::Mix, SyncMode::Ps);
    println!(
        "\nring-mix: {} ({:.2}x vs single) | ps-mix: {} ({:.2}x — parameter pulls dominate)",
        human_time(ring_mix.total_s),
        ring_mix.speedup(),
        human_time(ps_mix.total_s),
        ps_mix.speedup()
    );

    // 4. The collective itself is real: all-reduce 4 worker buffers and
    //    verify against the sequential sum.
    let mut rng = xenos::util::rng::Rng::new(3);
    let inputs: Vec<Vec<f32>> = (0..p).map(|_| rng.vec_uniform(1 << 16)).collect();
    let mut expect = vec![0.0f32; 1 << 16];
    for v in &inputs {
        for (e, x) in expect.iter_mut().zip(v) {
            *e += x;
        }
    }
    let reduced = ring::ring_allreduce_exec(inputs);
    let max_err = reduced
        .iter()
        .flat_map(|r| r.iter().zip(&expect).map(|(a, b)| (a - b).abs()))
        .fold(0.0f32, f32::max);
    println!("\nring all-reduce over {p} workers x 64K floats: max err {max_err:e}");
    assert!(max_err < 1e-3);
    println!("distributed_inference OK");
}
