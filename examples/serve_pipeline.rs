//! End-to-end serving driver — the session's required E2E validation.
//!
//! Loads the **real AOT-compiled model** (`artifacts/linked.hlo.txt`, the
//! Pallas linked-kernel variant lowered by `python/compile/aot.py`),
//! then:
//!
//! 1. runs the paper's §2.1 three-stage pipeline (acquisition →
//!    preprocess → inference) and reports the inference share;
//! 2. serves a batched request workload through the coordinator
//!    (router → dynamic batcher → PJRT workers) for BOTH model variants,
//!    reporting latency percentiles and throughput;
//! 3. cross-checks the two variants' outputs on the same inputs.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example serve_pipeline
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;

use xenos::runtime::{Engine, PjrtRuntime};
use xenos::serve::{self, Coordinator, PipelineConfig, ServeConfig};
use xenos::util::human_time;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string()),
    );

    // --- stage report: the §2.1 pipeline -------------------------------
    let rt = Arc::new(PjrtRuntime::load_dir(&dir)?);
    println!("loaded artifacts: {:?}", rt.variants());
    let engine = Engine::pjrt(rt.clone(), "linked")?;
    let pipe = serve::run_pipeline(&engine, PipelineConfig { frames: 64, src_hw: 32, seed: 9 })?;
    println!(
        "pipeline over {} frames: acquire {} | preprocess {} | inference {} ({:.0}% of total)",
        pipe.frames,
        human_time(pipe.acquire_s),
        human_time(pipe.preprocess_s),
        human_time(pipe.inference_s),
        pipe.inference_share() * 100.0
    );

    // --- cross-check: linked vs vanilla artifacts -----------------------
    let shape = rt.artifact("linked").unwrap().inputs[0].clone();
    let mut rng = xenos::util::rng::Rng::new(7);
    let x = xenos::ops::Tensor::new(
        xenos::graph::TensorDesc::plain(shape.clone()),
        rng.vec_uniform(shape.numel()),
    );
    let a = rt.execute("vanilla", std::slice::from_ref(&x))?;
    let b = rt.execute("linked", std::slice::from_ref(&x))?;
    let diff = a[0].max_abs_diff(&b[0]);
    println!("linked-vs-vanilla artifact max diff: {diff:.2e} (tolerance 1e-4)");
    assert!(diff < 1e-4);
    drop(engine);
    drop(rt);

    // --- batched serving workload for both variants ---------------------
    for variant in ["vanilla", "linked"] {
        let cfg = ServeConfig {
            workers: 2,
            batcher: serve::BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(1),
            },
            ..Default::default()
        };
        let dir2 = dir.clone();
        let report = Coordinator::new(cfg).run(
            move |_w| {
                let rt = Arc::new(PjrtRuntime::load_dir(&dir2)?);
                Engine::pjrt(rt, variant)
            },
            // ~150 req/s open-loop arrivals: below the 2-worker capacity so
            // latency reflects service time, not a saturated queue.
            serve::coordinator::synthetic_requests(vec![shape.clone()], 256, 150.0, 11),
        )?;
        println!(
            "[{variant:<7}] served {:>4} reqs, {:>8.1} req/s | latency p50 {} p90 {} p99 {} | exec p50 {} | mean batch {:.2}",
            report.served,
            report.throughput,
            human_time(report.latency.p50),
            human_time(report.latency.p90),
            human_time(report.latency.p99),
            human_time(report.exec.p50),
            report.batch_size.mean
        );
    }
    println!("serve_pipeline OK");
    Ok(())
}
